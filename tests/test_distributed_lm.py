"""Distributed LM integration (subprocess, 8 fake devices): sharded
train-step and context-parallel decode must match single-device numerics —
the long_500k cell's correctness story at test scale."""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.models import lm
    from repro.runtime.meshctx import use_mesh, logical_to_spec
    from repro.optim import adamw

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    cfg = lm.LMConfig(name="t", n_layers=2, d_model=64, n_heads=8,
                      n_kv_heads=4, d_ff=128, vocab=128, d_head=8,
                      loss_chunks=2)
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    # 1. sharded train step == unsharded
    opt = adamw(1e-3)
    def step(state, batch):
        p, o = state
        (l, m), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(p, batch, cfg)
        p, o, om = opt.update(g, o, p)
        return (p, o), l
    state0 = (params, opt.init(params))
    (_, l_plain) = jax.jit(step)(state0, batch)

    pspec = jax.tree.map(
        lambda spec: NamedSharding(mesh, logical_to_spec(spec, mesh)),
        lm.param_logical_specs(cfg), is_leaf=lambda x: isinstance(x, tuple))
    with use_mesh(mesh):
        sh_params = jax.tree.map(jax.device_put, params, pspec)
        st = (sh_params, opt.init(sh_params))
        bsh = {k: jax.device_put(v, NamedSharding(
            mesh, P("data", None))) for k, v in batch.items()}
        (_, l_shard) = jax.jit(step)(st, bsh)
    assert abs(float(l_plain) - float(l_shard)) < 2e-4, (l_plain, l_shard)

    # 2. context-parallel decode: cache sharded over ("data","model") on the
    # sequence dim == single-device decode (the long_500k layout)
    logits, cache = lm.prefill(params, toks, cfg, max_len=40)
    nxt = jnp.argmax(logits, -1)[:, None]
    ref_logits, _ = lm.decode_step(params, cache, nxt, cfg)

    cspec = NamedSharding(mesh, P(None, None, None, ("data", "model"), None))
    with use_mesh(mesh):
        sh_cache = {"k": jax.device_put(cache["k"], cspec),
                    "v": jax.device_put(cache["v"], cspec),
                    "length": cache["length"]}
        got_logits, new_cache = jax.jit(
            lambda p, c, t: lm.decode_step(p, c, t, cfg))(
            sh_params, sh_cache, nxt)
    np.testing.assert_allclose(np.asarray(got_logits),
                               np.asarray(ref_logits), rtol=2e-3, atol=2e-3)
    assert int(new_cache["length"]) == 33
    print("DISTRIBUTED-LM-OK")
""")


@pytest.mark.slow
def test_sharded_train_and_context_parallel_decode():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout[-2000:] + proc.stderr[-3000:]
    assert "DISTRIBUTED-LM-OK" in proc.stdout
