"""bst [recsys]: embed_dim=32 seq_len=20 n_blocks=1 n_heads=8
mlp=1024-512-256 interaction=transformer-seq — Behavior Sequence Transformer
(Alibaba) [arXiv:1905.06874; paper]"""
from repro.models.bst import BSTConfig

FAMILY = "recsys"

SHAPES = {
    "train_batch": {"kind": "train", "batch": 65_536},
    "serve_p99": {"kind": "serve", "batch": 512},
    "serve_bulk": {"kind": "serve", "batch": 262_144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

SMOKE_SHAPES = {
    "train_batch": {"kind": "train", "batch": 512},
    "serve_p99": {"kind": "serve", "batch": 128},
    "serve_bulk": {"kind": "serve", "batch": 1024},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 2048},
}


def full_config() -> BSTConfig:
    return BSTConfig(name="bst", embed_dim=32, seq_len=20, n_blocks=1,
                     n_heads=8, mlp_dims=(1024, 512, 256))


def smoke_config() -> BSTConfig:
    return BSTConfig(name="bst-smoke", embed_dim=16, seq_len=8, n_blocks=1,
                     n_heads=2, mlp_dims=(32, 16), item_vocab=1024,
                     profile_vocab=64, multihot_vocab=128, multihot_len=4)
