"""Worker for shard-scaling benchmarks: runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess.
Prints CSV rows:  name,us_per_call,derived

Covers 1-D slab layouts and 2-D/3-D block layouts at equal device counts,
so the strong/weak tables expose the surface-to-volume gain of the block
decomposition (ghost_bytes column).  The requested size is used verbatim —
an edge length or an exact "XxYxZ" extent; shapes that do not divide a
layout run the pad-and-mask path (deviation (p) in DESIGN.md) and the
derived column reports the per-block pad fraction.

Under ``--multihost`` the worker instead joins the real multi-process mesh
(`jax.distributed.initialize()`, coordinator from the launcher env) and
runs every layout that fits the global device count."""
import os
import sys

if "--multihost" in sys.argv:
    import jax
    jax.distributed.initialize()
else:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import compute_order, make_dpc_mesh
from repro.core.distributed import (distributed_manifold,
                                    distributed_connected_components)
from repro.configs.dpc_grid import SCALING_LAYOUTS
from repro.data import perlin_noise


def timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def _parse_size(spec: str):
    """"97x61x43" -> (97, 61, 43); a bare edge length -> a cube."""
    if "x" in spec:
        dims = tuple(int(t) for t in spec.split("x"))
        if len(dims) != 3:
            sys.exit(f"--size must be an edge length or XxYxZ, got {spec!r}")
        return dims
    return (int(spec),) * 3


def main():
    mode = sys.argv[1]           # "strong" | "weak"
    base = sys.argv[2]           # grid size (strong) / per-block (weak),
    base_dims = _parse_size(base)  # verbatim — never rounded to divisible
    ndev = len(jax.devices())
    for layout in SCALING_LAYOUTS:
        if int(np.prod(layout)) > ndev:
            print(f"# skipping layout {layout} ({ndev} devices)",
                  file=sys.stderr)
            continue
        pads = layout + (1,) * (3 - len(layout))
        if mode == "strong":
            dims = base_dims
        else:  # weak scaling: volume grows with the block lattice
            dims = tuple(b * p for b, p in zip(base_dims, pads))
        field = perlin_noise(dims, frequency=0.1, seed=0)
        order = compute_order(jnp.asarray(field))
        mask = jnp.asarray(field > np.quantile(field, 0.9))
        mesh = make_dpc_mesh(layout)
        tag = "x".join(map(str, layout))

        tab = "tab1" if mode == "strong" else "tab2"
        us, (labels, stats) = timeit(
            lambda o: distributed_manifold(o, mesh, 6, True), order)
        print(f"{tab}_{mode}_seg_{base}_{tag}blocks,{us:.0f},"
              f"ghost_bytes={int(stats.ghost_bytes)};"
              f"local_iters={int(stats.local_iters)};"
              f"table_iters={int(stats.table_iters)};"
              f"table_bytes={int(stats.table_bytes_peak)};"
              f"exchange_rounds={int(stats.exchange_rounds)};"
              f"pad_frac={float(stats.pad_fraction):.4f}", flush=True)

        us, (labels, stats) = timeit(
            lambda m: distributed_connected_components(m, mesh, 6), mask)
        print(f"{tab}_{mode}_cc_{base}_{tag}blocks,{us:.0f},"
              f"ghost_bytes={int(stats.ghost_bytes)};"
              f"masked_frac={float(stats.masked_ghost_fraction):.4f};"
              f"stitch_rounds={int(stats.stitch_rounds)};"
              f"table_bytes={int(stats.table_bytes_peak)};"
              f"exchange_rounds={int(stats.exchange_rounds)};"
              f"pad_frac={float(stats.pad_fraction):.4f}", flush=True)


if __name__ == "__main__":
    main()
