"""AdamW + friends, flax/optax-free.  Optimizer states mirror the param
pytree so the launcher shards them with the same PartitionSpecs (ZeRO-style:
FSDP-sharded params imply FSDP-sharded moments for free).

Moment dtype is configurable (fp32 default; bf16 halves optimizer HBM for
the trillion-param cells — see EXPERIMENTS.md §Perf)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree), norm


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, grad_clip: float = 1.0,
          moment_dtype=jnp.float32) -> Optimizer:
    """lr: float or schedule fn(step) -> float."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m32 = m.astype(jnp.float32) * b1 + g32 * (1 - b1)
            v32 = v.astype(jnp.float32) * b2 + g32 * g32 * (1 - b2)
            mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
            vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
            u = -lr_t * (mhat / (jnp.sqrt(vhat) + eps)
                         + weight_decay * p.astype(jnp.float32))
            return u, m32.astype(moment_dtype), v32.astype(moment_dtype)

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        updates = jax.tree.map(lambda t: t[0], out,
                               is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_params = apply_updates(params, updates)
        new_state = {"m": new_m, "v": new_v, "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def sgd(lr, momentum: float = 0.9, grad_clip: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        return {"m": jax.tree.map(lambda p: jnp.zeros_like(p,
                                                           dtype=jnp.float32),
                                  params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        if grad_clip:
            grads, gnorm = clip_by_global_norm(grads, grad_clip)
        else:
            gnorm = global_norm(grads)
        lr_t = lr_fn(step)
        new_m = jax.tree.map(
            lambda m, g: m * momentum + g.astype(jnp.float32), state["m"],
            grads)
        updates = jax.tree.map(lambda m: -lr_t * m, new_m)
        return (apply_updates(params, updates),
                {"m": new_m, "step": step},
                {"grad_norm": gnorm, "lr": lr_t})

    return Optimizer(init, update)
