"""Dry-run regression: representative cells must lower + compile on the
production meshes (512 fake host devices, subprocess).  The full cell
matrix runs via `python -m repro.launch.dryrun`; this keeps CI fast."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_CELLS = [
    ("llama3_2_1b", "train_4k", "--single-pod"),
    ("kimi_k2_1t", "decode_32k", "--multi-pod"),
    ("gat_cora", "ogb_products", "--single-pod"),
    ("bst", "retrieval_cand", "--multi-pod"),
    ("dpc_grid", "cc_512", "--single-pod"),
    # prime extents over the 8x8x4 block mesh: the pad-and-mask path
    ("dpc_grid", "cc_ragged", "--single-pod"),
]


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape,mesh", _CELLS)
def test_smoke_cell_compiles(arch, shape, mesh, tmp_path):
    env = dict(os.environ, PYTHONPATH=os.path.join(_ROOT, "src"))
    env.pop("XLA_FLAGS", None)  # dryrun.py sets its own 512-device flag
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--smoke",
         "--arch", arch, "--shape", shape, mesh, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=900, cwd=_ROOT)
    assert proc.returncode == 0, proc.stdout[-3000:] + proc.stderr[-3000:]
    assert "0 failures" in proc.stdout
