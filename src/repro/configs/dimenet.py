"""dimenet [gnn]: n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6  [arXiv:2003.03123; unverified]"""
from repro.models.gnn import DimeNetConfig
from .gnn_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "gnn"


def full_config() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet", n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6)


def smoke_config() -> DimeNetConfig:
    return DimeNetConfig(name="dimenet-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=4, n_spherical=3, n_radial=4)
