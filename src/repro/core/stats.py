"""Unified DPC run statistics for both distributed backends.

`DPCStats` (structured block lattice) and `GraphDPCStats` (unstructured
vertex partitions) report the SAME seven fields in the SAME order, so the
serving layer and the benchmarks can consume either through one code path:
shared fields first (`local_iters`, `table_iters`, `stitch_rounds`,
`ghost_bytes`, `masked_ghost_fraction`, `pad_fraction`, `comm_phases`).
Both expose `as_dict()`, the host-side uniform reporting hook — values are
converted to python scalars (or lists, for the batched entry points whose
stats carry a leading request dim), never jax arrays.

The classes stay distinct NamedTuples (not one shared class) on purpose:
each is an output pytree of its backend's `shard_map` and is constructed
per-device under tracing; keeping them separate lets a backend grow a
backend-specific trailing field later without perturbing the shared prefix
the serving layer keys on.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax

# the shared field prefix, in the canonical order both classes use
STAT_FIELDS = ("local_iters", "table_iters", "stitch_rounds", "ghost_bytes",
               "masked_ghost_fraction", "pad_fraction", "comm_phases",
               "kernel_rounds", "global_iters_saved", "table_bytes_peak",
               "exchange_rounds", "converged")


def stats_as_dict(stats) -> dict:
    """Host-side uniform view of any *DPCStats NamedTuple: python scalars
    (0-d) or lists (batched stats with a leading request dim)."""
    out = {}
    for name, val in zip(stats._fields, stats):
        a = np.asarray(val)
        out[name] = a.item() if a.ndim == 0 else a.tolist()
    return out


class DPCStats(NamedTuple):
    """Per-run statistics of the structured (block-lattice) backend."""
    local_iters: jax.Array      # pointer-doubling rounds in the local phase
    table_iters: jax.Array      # rounds on the gathered ghost table
    stitch_rounds: jax.Array    # CC only (0 for MS)
    ghost_bytes: jax.Array      # in-domain bytes all-gathered (the ONE comm
                                # phase; pad slots excluded, deviation (p))
    masked_ghost_fraction: jax.Array  # CC: fraction of boundary actually
                                      # masked (over in-domain slots)
    pad_fraction: jax.Array     # fraction of block cells that are padding
                                # (0 whenever the layout divides the grid)
    comm_phases: jax.Array      # bulk exchange phases traced (paper budget:
                                # 1; the halo ppermute is ghost setup, not a
                                # gather phase)
    kernel_rounds: jax.Array    # max in-tile saturation rounds of the fused
                                # local-phase kernel (0 on the jnp fallback)
    global_iters_saved: jax.Array  # provable lower bound on doubling rounds
                                   # the fusion removed from the global loop:
                                   # max(kernel_rounds - local_iters, 0) —
                                   # the unfused loop needs >= kernel_rounds
                                   # rounds to resolve the same chains
    table_bytes_peak: jax.Array    # per-device bytes materialized for the
                                   # boundary-table resolution (replicated:
                                   # the full gathered table; sharded: own
                                   # faces + halo stack, deviation (s))
    exchange_rounds: jax.Array     # sharded mode: outer halo-exchange rounds
                                   # of the table fixpoint (0 = replicated)
    converged: jax.Array           # 1 iff every table fixpoint reached its
                                   # fixed point within max_iter (a 0 here
                                   # raises eagerly; see _table.check_converged)

    def as_dict(self) -> dict:
        return stats_as_dict(self)


class GraphDPCStats(NamedTuple):
    """Per-run statistics of the unstructured (vertex-partition) backend.
    Field names/order mirror `DPCStats` exactly (see module docstring)."""
    local_iters: jax.Array      # pointer-doubling rounds in the local phase
    table_iters: jax.Array      # chase + propagate rounds on the cut table
    stitch_rounds: jax.Array    # local stitch fixpoint rounds
    ghost_bytes: jax.Array      # real cut bytes all-gathered (the ONE comm
                                # phase; pad slots excluded, deviation (p))
    masked_ghost_fraction: jax.Array  # fraction of REAL cut slots masked
    pad_fraction: jax.Array     # fraction of owned slots that are padding
                                # (0 for a balanced partition)
    comm_phases: jax.Array      # all_gather phases traced (paper budget: 1)
    kernel_rounds: jax.Array    # always 0: the fused grid kernel does not
                                # apply to unstructured partitions
    global_iters_saved: jax.Array  # always 0 (see kernel_rounds)
    table_bytes_peak: jax.Array    # per-device bytes materialized for the
                                   # cut-table resolution (replicated: full
                                   # gathered table (+mask); sharded: own
                                   # row + neighbor halo, deviation (s))
    exchange_rounds: jax.Array     # sharded mode: outer halo-exchange rounds
                                   # of the cut fixpoint (0 = replicated)
    converged: jax.Array           # 1 iff every table fixpoint reached its
                                   # fixed point within max_iter

    def as_dict(self) -> dict:
        return stats_as_dict(self)


assert DPCStats._fields == STAT_FIELDS == GraphDPCStats._fields
