"""Cell construction: one (architecture x input-shape) cell = a step
function + abstract input shapes + shardings for a given mesh.  The dry-run
lowers and compiles every cell; train/serve launchers feed the same cells
real data."""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.models import lm, gnn, bst
from repro.optim import adamw
from repro.runtime.meshctx import logical_to_spec
from repro.launch.mesh import make_flat_mesh


def S(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    family: str
    cfg: Any
    shape: dict
    step_fn: Callable
    arg_shapes: tuple           # pytree of ShapeDtypeStruct
    arg_shardings: tuple        # matching NamedShardings
    donate_argnums: tuple = ()
    note: str = ""

    @property
    def name(self):
        return f"{self.arch_id}:{self.shape_name}"


def _ns(mesh, logical_tree):
    """Translate a pytree of logical-axis tuples into NamedShardings."""
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, logical_to_spec(spec, mesh)),
        logical_tree, is_leaf=lambda x: isinstance(x, tuple))


def _like(tree, fn):
    return jax.tree.map(fn, tree)


def _dp_size(mesh: Mesh) -> int:
    n = 1
    for a in ("pod", "data"):
        if a in mesh.axis_names:
            n *= mesh.shape[a]
    return n


# --- LM cells -----------------------------------------------------------------


def _lm_state_shapes(cfg, optimizer):
    params = jax.eval_shape(partial(lm.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    opt = jax.eval_shape(optimizer.init, params)
    return params, opt


def _lm_state_specs(cfg, mesh):
    pspec = lm.param_logical_specs(cfg)
    params = _ns(mesh, pspec)
    mom = _ns(mesh, pspec)
    opt = {"m": mom, "v": _ns(mesh, pspec),
           "step": NamedSharding(mesh, P())}
    return params, opt


def _cache_logical(cfg, shape_name):
    """KV cache (L, B, Hkv, S, dh): context-parallel on the cache sequence;
    batch on dp when it shards."""
    if shape_name == "long_500k":
        return (None, None, None, "ep_all", None)
    return (None, "dp", None, "sp", None)


def build_lm_cell(arch_id, mod, shape_name, shape, mesh, smoke) -> Cell:
    cfg = mod.smoke_config() if smoke else mod.full_config()
    if cfg.moe is not None:
        # local (shard-local) dispatch needs the static dp size
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, dp_shards=_dp_size(mesh)))
    b, sq = shape["batch"], shape["seq"]
    kind = shape["kind"]
    tok = S((b, sq), jnp.int32)
    tok_sh = NamedSharding(mesh, logical_to_spec(("dp", None), mesh))

    if kind == "train":
        opt = adamw(1e-4, moment_dtype=cfg.opt_moment_dtype)
        pshape, oshape = _lm_state_shapes(cfg, opt)
        pspec, ospec = _lm_state_specs(cfg, mesh)

        def step(state, batch):
            params, ostate = state
            (loss, metrics), grads = jax.value_and_grad(
                lm.loss_fn, has_aux=True)(params, batch, cfg)
            params, ostate, om = opt.update(grads, ostate, params)
            return (params, ostate), {"loss": loss, **metrics, **om}

        return Cell(arch_id, shape_name, "lm", cfg, shape, step,
                    ((pshape, oshape), {"tokens": tok, "labels": tok}),
                    ((pspec, ospec), {"tokens": tok_sh, "labels": tok_sh}),
                    donate_argnums=(0,))

    pshape = jax.eval_shape(partial(lm.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    pspec = _ns(mesh, lm.param_logical_specs(cfg))

    if kind == "prefill":
        def step(params, tokens):
            return lm.prefill(params, tokens, cfg)
        return Cell(arch_id, shape_name, "lm", cfg, shape, step,
                    (pshape, tok), (pspec, tok_sh))

    # decode: one new token against a seq-long cache
    cache_shape = jax.eval_shape(
        partial(lm.init_kv_cache, cfg, b, sq))
    clog = _cache_logical(cfg, shape_name)
    cache_spec = {
        "k": NamedSharding(mesh, logical_to_spec(clog, mesh)),
        "v": NamedSharding(mesh, logical_to_spec(clog, mesh)),
        "length": NamedSharding(mesh, P()),
    }
    new_tok = S((b, 1), jnp.int32)
    new_tok_sh = NamedSharding(
        mesh, logical_to_spec(("dp", None) if b > 1 else (None, None), mesh))

    def step(params, cache, tokens):
        return lm.decode_step(params, cache, tokens, cfg)

    return Cell(arch_id, shape_name, "lm", cfg, shape, step,
                (pshape, cache_shape, new_tok),
                (pspec, cache_spec, new_tok_sh), donate_argnums=(1,))


# --- GNN cells ----------------------------------------------------------------


def _pad512(n: int) -> int:
    """Round a sharded leading dim up to a 512 multiple so the same cell
    lowers on both production meshes (padding is masked; standard practice
    for uneven graph partitions — noted in EXPERIMENTS.md §Dry-run)."""
    return ((n + 511) // 512) * 512


def _graph_shapes(arch, cfg, shp, smoke):
    """Abstract GraphBatch for the cell (DESIGN.md: feature semantics are
    adapted per arch — geometric models get positions/species, attribute
    models get d_feat features)."""
    kind = shp["kind"]
    if kind == "batched":
        n = shp["batch"] * shp["n_nodes"]
        e = shp["batch"] * shp["n_edges"]
        g = shp["batch"]
    elif kind == "sampled":
        n, e, g = shp["sample_nodes"], shp["sample_edges"], 1
    else:
        n, e, g = shp["n_nodes"], shp["n_edges"], 1
    n, e = _pad512(n), _pad512(e)
    t = 4 * e  # triplet budget (dimenet)
    base = {
        "senders": S((e,), jnp.int32), "receivers": S((e,), jnp.int32),
        "node_mask": S((n,), jnp.bool_), "edge_mask": S((e,), jnp.bool_),
        "graph_ids": S((n,), jnp.int32),
    }
    if arch == "gat":
        base["node_feat"] = S((n, shp.get("d_feat", 32)), jnp.float32)
        base["labels"] = S((n,), jnp.int32)
    elif arch == "meshgraphnet":
        base["node_feat"] = S((n, cfg.d_node_in), jnp.float32)
        base["edge_feat"] = S((e, cfg.d_edge_in), jnp.float32)
        base["labels"] = S((n, cfg.d_out), jnp.float32)
    else:  # geometric: schnet / dimenet
        base["node_feat"] = S((n, 1), jnp.float32)   # species
        base["positions"] = S((n, 3), jnp.float32)
        base["labels"] = S((g,), jnp.float32)
        if arch == "dimenet":
            base["triplet_src"] = S((t,), jnp.int32)
            base["triplet_dst"] = S((t,), jnp.int32)
            base["triplet_mask"] = S((t,), jnp.bool_)
    return base, g


def _adapt_gnn_cfg(cfg, shp):
    if cfg.arch == "gat":
        return dataclasses.replace(cfg, d_in=shp.get("d_feat", 32),
                                   n_classes=shp.get("n_classes", 7))
    if cfg.arch == "meshgraphnet" and "d_feat" in shp:
        return dataclasses.replace(cfg, d_node_in=shp["d_feat"])
    return cfg


def build_gnn_cell(arch_id, mod, shape_name, shape, mesh, smoke) -> Cell:
    cfg = mod.smoke_config() if smoke else mod.full_config()
    cfg = _adapt_gnn_cfg(cfg, shape)
    graph_shapes, n_graphs = _graph_shapes(cfg.arch, cfg, shape, smoke)
    params = jax.eval_shape(partial(gnn.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    opt = adamw(1e-4)
    oshape = jax.eval_shape(opt.init, params)

    # graph arrays sharded over every mesh axis on the leading dim (padded
    # to 512 multiples); small per-graph arrays (energy labels) replicate;
    # model params replicated (they are tiny) — DESIGN.md §5
    total = int(np.prod([mesh.shape[a] for a in mesh.axis_names]))

    def gspec(v):
        if v.shape and v.shape[0] % total == 0:
            return NamedSharding(mesh, logical_to_spec(
                ("ep_all",) + (None,) * (len(v.shape) - 1), mesh))
        return NamedSharding(mesh, P())

    gshard = {k: gspec(v) for k, v in graph_shapes.items()}
    repl = jax.tree.map(lambda _: NamedSharding(mesh, P()), params)
    orepl = jax.tree.map(lambda _: NamedSharding(mesh, P()), oshape)

    def step(state, graph):
        p, o = state
        graph = dict(graph, n_graphs=n_graphs)
        (loss, metrics), grads = jax.value_and_grad(
            gnn.loss_fn, has_aux=True)(p, graph, cfg)
        p, o, om = opt.update(grads, o, p)
        return (p, o), {"loss": loss, **metrics, **om}

    return Cell(arch_id, shape_name, "gnn", cfg, shape, step,
                ((params, oshape), graph_shapes),
                ((repl, orepl), gshard), donate_argnums=(0,))


# --- BST cells ----------------------------------------------------------------


def build_bst_cell(arch_id, mod, shape_name, shape, mesh, smoke) -> Cell:
    cfg = mod.smoke_config() if smoke else mod.full_config()
    b = shape["batch"]
    kind = shape["kind"]
    params = jax.eval_shape(partial(bst.init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    pspec = _ns(mesh, bst.param_logical_specs(cfg))
    dp = lambda nd: NamedSharding(
        mesh, logical_to_spec(("dp",) + (None,) * (nd - 1), mesh))
    batch_shapes = {
        "hist_items": S((b, cfg.seq_len), jnp.int32),
        "target_item": S((b,), jnp.int32),
        "profile_ids": S((b, cfg.n_profile_fields), jnp.int32),
        "multihot_ids": S((b, cfg.n_multihot_fields, cfg.multihot_len),
                          jnp.int32),
    }
    if kind == "train":
        batch_shapes["labels"] = S((b,), jnp.float32)
    bshard = {k: dp(len(v.shape)) for k, v in batch_shapes.items()}
    if b == 1:  # retrieval_cand: can't shard a singleton batch
        bshard = {k: NamedSharding(mesh, P()) for k in batch_shapes}

    if kind == "train":
        opt = adamw(1e-3)
        oshape = jax.eval_shape(opt.init, params)
        ospec = {"m": _ns(mesh, bst.param_logical_specs(cfg)),
                 "v": _ns(mesh, bst.param_logical_specs(cfg)),
                 "step": NamedSharding(mesh, P())}

        def step(state, batch):
            p, o = state
            (loss, metrics), grads = jax.value_and_grad(
                bst.loss_fn, has_aux=True)(p, batch, cfg)
            p, o, om = opt.update(grads, o, p)
            return (p, o), {"loss": loss, **metrics, **om}

        return Cell(arch_id, shape_name, "recsys", cfg, shape, step,
                    ((params, oshape), batch_shapes),
                    ((pspec, ospec), bshard), donate_argnums=(0,))

    if kind == "serve":
        def step(params, batch):
            return bst.forward(params, batch, cfg)
        return Cell(arch_id, shape_name, "recsys", cfg, shape, step,
                    (params, batch_shapes), (pspec, bshard))

    # retrieval: candidate axis sharded on "data" (1M % 512 != 0; data=16
    # divides it on both meshes — noted in EXPERIMENTS.md §Dry-run)
    nc = shape["n_candidates"]
    batch_shapes["candidates"] = S((b, nc), jnp.int32)
    bshard["candidates"] = NamedSharding(
        mesh, logical_to_spec((None, "fsdp"), mesh))

    def step(params, batch):
        return bst.retrieval_step(params, batch, cfg, top_k=100)

    return Cell(arch_id, shape_name, "recsys", cfg, shape, step,
                (params, batch_shapes), (pspec, bshard))


# --- DPC cells (the paper's own workload) --------------------------------------


def build_dpc_cell(arch_id, mod, shape_name, shape, mesh, smoke) -> Cell:
    from repro.core.distributed import (distributed_manifold,
                                        distributed_connected_components)
    from repro.launch.mesh import make_block_mesh
    cfg = mod.smoke_config() if smoke else mod.full_config()
    dims = shape["dims"]
    # block decomposition from the config when it matches the device count;
    # otherwise the flat 1-D slab mesh.  The grid does NOT need to divide
    # the layout: ragged extents are padded and masked inside the core
    # (deviation (p) in DESIGN.md)
    layout = tuple(getattr(cfg, "layout", ()) or ())
    n_dev = mesh.devices.size
    if layout and math.prod(layout) == n_dev and len(layout) <= len(dims):
        dpc_mesh = make_block_mesh(layout, mesh)
        note = f"lowered on the {'x'.join(map(str, layout))} block mesh"
        if any(d % p for d, p in zip(dims, layout)):
            note += " (ragged extents, pad-and-mask)"
    else:
        dpc_mesh = make_flat_mesh(mesh)
        note = "lowered on the flattened 1-D mesh"
        if dims[0] % n_dev:
            note += " (ragged extents, pad-and-mask)"
    names = tuple(dpc_mesh.axis_names)
    # jit inputs must divide the mesh axes they shard over; a ragged axis
    # arrives replicated and the core pads + reshards it under shard_map
    axes = [nm if dims[i] % dpc_mesh.shape[nm] == 0 else None
            for i, nm in enumerate(names)]
    sh = NamedSharding(dpc_mesh,
                       P(*axes, *([None] * (len(dims) - len(names)))))

    if shape["kind"] == "dpc":
        inp = S(dims, jnp.int32)

        def step(order):
            labels, stats = distributed_manifold(order, dpc_mesh,
                                                 cfg.connectivity)
            return labels, stats
    else:
        inp = S(dims, jnp.bool_)

        def step(mask):
            labels, stats = distributed_connected_components(
                mask, dpc_mesh, cfg.connectivity,
                gather_mask=getattr(cfg, "gather_mask", True))
            return labels, stats

    return Cell(arch_id, shape_name, "dpc", cfg, shape, step,
                (inp,), (sh,), note=note)


def build_dpc_graph_cell(arch_id, mod, shape_name, shape, mesh, smoke) -> Cell:
    """Distributed CC on an unstructured edge-list mesh: a 1-D vertex
    partition over the flattened device mesh (DESIGN.md §5; the partition
    geometry is table-driven, so no block lattice applies)."""
    from repro.core.distributed_graph import (
        GraphDecomp, distributed_connected_components_graph)
    from repro.data import grid_edge_list
    from repro.data.graphs import random_csr
    cfg = mod.smoke_config() if smoke else mod.full_config()
    if shape["kind"] == "graph_cc":
        n = math.prod(shape["dims"])
        senders, receivers = grid_edge_list(shape["dims"], cfg.connectivity)
    else:  # graph_cc_random
        n = shape["n"]
        indptr, receivers = random_csr(n, shape["avg_degree"], seed=0)
        senders = np.repeat(np.arange(n, dtype=np.int64), np.diff(indptr))
    dpc_mesh = make_flat_mesh(mesh)
    ndev = int(dpc_mesh.devices.size)
    dec = GraphDecomp(n, senders, receivers, ndev)
    inp = S((n,), jnp.bool_)
    sh = NamedSharding(dpc_mesh, P())   # global mask; ghosts ride the scatter
    geometry = bool(shape.get("geometry", False))

    def step(mask):
        # pure-geometry shapes label the mesh connectivity itself (paper:
        # CC "computed on pure geometry without any scalar data")
        if geometry:
            mask = jnp.ones_like(mask)
        return distributed_connected_components_graph(
            mask, dec, dpc_mesh, gather_mask=getattr(cfg, "gather_mask",
                                                     True))

    return Cell(arch_id, shape_name, "dpc_graph", cfg, shape, step,
                (inp,), (sh,),
                note=f"{ndev}-way vertex partition, "
                     f"{dec.table_size}-slot cut table, "
                     f"owned-pad {dec.pad_fraction:.3f}")


# --- registry -----------------------------------------------------------------

_BUILDERS = {"lm": build_lm_cell, "gnn": build_gnn_cell,
             "recsys": build_bst_cell, "dpc": build_dpc_cell,
             "dpc_graph": build_dpc_graph_cell}


def build_cell(arch_id: str, shape_name: str, mesh: Mesh,
               smoke: bool = False, cfg_transform=None) -> Cell:
    """cfg_transform(cfg) -> cfg lets the roofline tooling lower
    layer-count variants (lax.scan bodies are cost-analyzed once, so
    per-layer costs are recovered by extrapolating L=1 vs L=2 lowers)."""
    mod = configs.get(arch_id)
    shapes = mod.SMOKE_SHAPES if smoke else mod.SHAPES
    if shape_name not in shapes:
        raise KeyError(f"{arch_id} has no shape {shape_name}; "
                       f"options: {list(shapes)}")
    if cfg_transform is not None:
        mod = _TransformedModule(mod, cfg_transform)
    return _BUILDERS[mod.FAMILY](arch_id, mod, shape_name,
                                 shapes[shape_name], mesh, smoke)


class _TransformedModule:
    def __init__(self, mod, transform):
        self._mod = mod
        self._transform = transform

    def __getattr__(self, name):
        return getattr(self._mod, name)

    def full_config(self):
        return self._transform(self._mod.full_config())

    def smoke_config(self):
        return self._transform(self._mod.smoke_config())


def all_cells(include_dpc: bool = True):
    """The full assignment matrix: 10 archs x 4 shapes (+ DPC cells)."""
    out = []
    for arch in configs.ARCH_IDS:
        if arch in ("dpc_grid", "dpc_graph") and not include_dpc:
            continue
        mod = configs.get(arch)
        for shape_name in mod.SHAPES:
            out.append((arch, shape_name))
    return out
