"""Gradient compression for cross-pod reduction (distributed-optimization
tricks at 1000+ node scale).

Two codecs, both with error feedback (Karimireddy et al., "EF-SGD"):
  * top-k sparsification — keep the k largest-magnitude entries per tensor;
  * int8 quantization — per-tensor symmetric scale.

At multi-pod scale the DCN (inter-pod) all-reduce is the scarce resource;
the launcher applies the codec to the *pod-axis* reduction only (intra-pod
ICI reductions stay exact), which is how production systems deploy these.
The codecs are pure functions so they compose with jit/shard_map, and the
error-feedback residual lives in the optimizer state pytree (sharded like
the grads)."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class ErrorFeedbackState(NamedTuple):
    residual: object  # pytree like grads


def topk_compress_decompress(g: jax.Array, frac: float = 0.01):
    """Simulate top-k sparsify->reduce->densify on one tensor; returns the
    densified tensor (entries below the magnitude cutoff zeroed) and the
    fraction of L2 mass kept.  k = max(1, frac * size)."""
    flat = g.ravel().astype(jnp.float32)
    k = max(1, int(frac * flat.size))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    kept = jnp.where(jnp.abs(flat) >= thresh, flat, 0.0)
    mass = jnp.sum(kept * kept) / jnp.maximum(jnp.sum(flat * flat), 1e-20)
    return kept.reshape(g.shape).astype(g.dtype), mass


def int8_compress_decompress(g: jax.Array):
    """Per-tensor symmetric int8 quantize->dequantize round trip."""
    flat = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(flat)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(flat / scale), -127, 127).astype(jnp.int8)
    return (q.astype(jnp.float32) * scale).astype(g.dtype)


def compressed_gradients(grads, ef: ErrorFeedbackState | None,
                         codec: str = "int8", topk_frac: float = 0.01):
    """Apply codec with error feedback across a grad pytree.

    Returns (compressed_grads, new_ef).  The compressed grads are what the
    cross-pod all-reduce would carry; the residual (what compression dropped)
    is replayed into the next step's grads, preserving convergence."""
    if ef is None:
        ef = ErrorFeedbackState(jax.tree.map(
            lambda g: jnp.zeros_like(g, dtype=jnp.float32), grads))

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        if codec == "int8":
            out = int8_compress_decompress(corrected)
        elif codec == "topk":
            out, _ = topk_compress_decompress(corrected, topk_frac)
        else:
            raise ValueError(codec)
        return out.astype(g.dtype), corrected - out.astype(jnp.float32)

    pairs = jax.tree.map(one, grads, ef.residual)
    comp = jax.tree.map(lambda t: t[0], pairs,
                        is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return comp, ErrorFeedbackState(resid)
