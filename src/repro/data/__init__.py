from .perlin import perlin_noise
