"""Deterministic synthetic request workloads for the topology engine.

Shared by the throughput benchmark (`benchmarks/run.py serve_throughput`),
the serving launcher (`python -m repro.launch.serve --topology`) and the
runnable demo (`examples/serve_topology.py`): a seeded mix of CC /
MS-segmentation / threshold-sweep requests over a rotating set of grid
extents — the "many small heterogeneous tenants" traffic shape the engine
buckets.  Every request is a pure function of (seed, index), so repeated
workloads exercise the executable cache the way real repeated-layout
traffic does.

Reproducibility contract: `seed` is EXPLICIT (no default — a CI failure
must name the seed that produced it), and a workload is replayable from a
`WorkloadTrace` value alone: the trace records every generation parameter
plus the open-loop arrival/deadline schedule, and `trace.requests()`
regenerates the identical request list anywhere (`trace.as_dict()` is the
JSON-safe form for bug reports and bench artifacts).
"""
from __future__ import annotations

import dataclasses

import numpy as np
import jax.numpy as jnp

from ..core.ids import compute_order
from ..topology import TopologyRequest

_DEFAULT_MIX = (("cc", 0.5), ("ms", 0.2), ("manifold", 0.1),
                ("threshold_sweep", 0.2))


def synthetic_requests(n_requests: int, shapes, mix=None, connectivity=6,
                       sweep_k: int = 4, *, seed: int, backend: str = "pure",
                       mesh=None, table_mode: str = "replicated") -> list:
    """A deterministic list of mixed TopologyRequests.

    shapes: tuple of grid extents to rotate through; mix: tuple of
    (query, weight) over {"cc", "ms", "manifold", "threshold_sweep"};
    seed: required keyword — the single knob that reproduces a workload.
    `table_mode` applies to distributed backends only (sharded boundary
    table, deviation (s)); request contents are independent of it.
    """
    mix = mix or _DEFAULT_MIX
    queries = [q for q, _ in mix]
    weights = np.asarray([w for _, w in mix], dtype=float)
    weights = weights / weights.sum()
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        shape = shapes[int(rng.integers(len(shapes)))]
        query = queries[int(rng.choice(len(queries), p=weights))]
        field = rng.standard_normal(shape)
        common = dict(connectivity=connectivity, backend=backend, mesh=mesh,
                      tag=i)
        if backend == "distributed":
            common["table_mode"] = table_mode
        if query == "cc":
            reqs.append(TopologyRequest(
                "cc", mask=jnp.asarray(field > rng.uniform(-0.5, 0.5)),
                **common))
        elif query in ("ms", "manifold"):
            reqs.append(TopologyRequest(
                query, order=compute_order(jnp.asarray(field)),
                descending=bool(i % 2), **common))
        else:
            thr = np.quantile(field, np.linspace(0.2, 0.9, sweep_k))
            reqs.append(TopologyRequest(
                "threshold_sweep", field=jnp.asarray(field),
                thresholds=jnp.asarray(thr), **common))
    return reqs


def open_loop_arrivals(n_requests: int, rate: float, *, seed: int,
                       deadline_slack: float | None = None) -> tuple:
    """Open-loop (Poisson) arrival schedule: `n_requests` pairs of
    (arrival_time, deadline-or-None) with exponential inter-arrivals at
    `rate` requests per time unit.  Deadlines, when `deadline_slack` is
    set, are `arrival + U(0.5, 1.5) * deadline_slack` — jittered so a
    trace mixes tight and loose deadlines.  Deterministic in `seed`
    (a separate stream from the payload RNG, so arrival timing never
    perturbs request contents)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, 0xA11, 1]))
    t = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    if deadline_slack is None:
        return tuple((float(ti), None) for ti in t)
    slack = rng.uniform(0.5, 1.5, size=n_requests) * deadline_slack
    return tuple((float(ti), float(ti + si)) for ti, si in zip(t, slack))


@dataclasses.dataclass(frozen=True)
class WorkloadTrace:
    """A replayable workload: generation parameters + arrival schedule.

    The trace IS the workload — `requests()` regenerates the identical
    request list from the recorded parameters, and `arrivals` carries the
    per-request (arrival_time, deadline) pairs (empty for closed-loop
    traces).  Frozen and JSON-safe so a failing CI run can dump it and a
    local session can replay it verbatim."""
    seed: int
    n_requests: int
    shapes: tuple
    mix: tuple = _DEFAULT_MIX
    connectivity: int = 6
    sweep_k: int = 4
    arrivals: tuple = ()     # ((arrival_time, deadline-or-None), ...) or ()

    def requests(self, backend: str = "pure", mesh=None,
                 table_mode: str = "replicated") -> list:
        return synthetic_requests(
            self.n_requests, self.shapes, mix=self.mix,
            connectivity=self.connectivity, sweep_k=self.sweep_k,
            seed=self.seed, backend=backend, mesh=mesh,
            table_mode=table_mode)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["shapes"] = [list(s) for s in self.shapes]
        d["mix"] = [[q, w] for q, w in self.mix]
        d["arrivals"] = [list(a) for a in self.arrivals]
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "WorkloadTrace":
        return cls(seed=int(d["seed"]), n_requests=int(d["n_requests"]),
                   shapes=tuple(tuple(s) for s in d["shapes"]),
                   mix=tuple((q, float(w)) for q, w in d["mix"]),
                   connectivity=int(d["connectivity"]),
                   sweep_k=int(d["sweep_k"]),
                   arrivals=tuple(
                       (float(t), None if dl is None else float(dl))
                       for t, dl in d["arrivals"]))


def synthetic_trace(n_requests: int, shapes, mix=None, connectivity=6,
                    sweep_k: int = 4, *, seed: int, rate: float | None = None,
                    deadline_slack: float | None = None) -> WorkloadTrace:
    """Build a replayable trace; `rate` adds an open-loop arrival schedule
    (and `deadline_slack` per-request deadlines) for the async plane."""
    arrivals = (() if rate is None else
                open_loop_arrivals(n_requests, rate, seed=seed,
                                   deadline_slack=deadline_slack))
    return WorkloadTrace(seed=int(seed), n_requests=int(n_requests),
                         shapes=tuple(tuple(s) for s in shapes),
                         mix=tuple(mix or _DEFAULT_MIX),
                         connectivity=int(connectivity),
                         sweep_k=int(sweep_k), arrivals=arrivals)


def overload_trace(n_requests: int, shapes, mix=None, connectivity=6,
                   sweep_k: int = 4, *, seed: int, sustainable_rps: float,
                   factor: float = 4.0,
                   deadline_periods: float = 2.0) -> WorkloadTrace:
    """An oversubscribed open-loop trace for exercising admission control
    and load shedding (DESIGN.md §Serve-v3): Poisson arrivals at `factor`
    times a measured sustainable rate, with deadlines about
    `deadline_periods` mean service periods out — tight enough that a
    `factor`x backlog makes many of them unmeetable.  `sustainable_rps`
    should come from a measurement (e.g. the warm closed-loop rate of the
    `serve_throughput` bench); everything else is deterministic in `seed`,
    so the SAME trace value replays the same overload anywhere."""
    if sustainable_rps <= 0:
        raise ValueError(f"sustainable_rps must be > 0, "
                         f"got {sustainable_rps}")
    return synthetic_trace(
        n_requests, shapes, mix=mix, connectivity=connectivity,
        sweep_k=sweep_k, seed=seed,
        rate=float(factor) * float(sustainable_rps),
        deadline_slack=float(deadline_periods) / float(sustainable_rps))
