"""Steepest-neighbor initialisation (paper Alg. 1 lines 3-5, Alg. 3 line 6).

Two mesh regimes:
  * structured grids — stencil shifts over the axis/Freudenthal neighborhood
    (TTK's implicit triangulation of a structured grid yields the 14-neighbor
    Kuhn/Freudenthal stencil in 3D, 6-neighbor in 2D);
  * unstructured graphs — edge lists + `segment_max`, the same gather/scatter
    regime as GNN message passing.

`descending=True` points each vertex at its largest-order neighbor (steepest
ascent -> descending manifold terminating in maxima); `descending=False`
flips the order field (steepest descent -> ascending manifold / minima).
A vertex larger than all its neighbors points at itself (root).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from .ids import inverse_permutation

# --- neighborhood offset tables -------------------------------------------

_OFF_2D_4 = [(1, 0), (-1, 0), (0, 1), (0, -1)]
# Freudenthal triangulation of a 2D grid: axis edges + one diagonal
_OFF_2D_6 = _OFF_2D_4 + [(1, 1), (-1, -1)]
_OFF_3D_6 = [(1, 0, 0), (-1, 0, 0), (0, 1, 0), (0, -1, 0), (0, 0, 1), (0, 0, -1)]
# Kuhn/Freudenthal 3D: all nonzero {0,1}^3 offsets and their negatives
_OFF_3D_14 = _OFF_3D_6 + [
    (1, 1, 0), (-1, -1, 0), (0, 1, 1), (0, -1, -1),
    (1, 0, 1), (-1, 0, -1), (1, 1, 1), (-1, -1, -1),
]
# full digital-topology neighborhoods: 26 = every nonzero {-1,0,1}^3 offset,
# 18 = the subset sharing a face or an edge (no corner diagonals)
_OFF_3D_26 = [(i, j, k)
              for i in (-1, 0, 1) for j in (-1, 0, 1) for k in (-1, 0, 1)
              if (i, j, k) != (0, 0, 0)]
_OFF_3D_18 = [off for off in _OFF_3D_26 if sum(abs(o) for o in off) <= 2]


def neighbor_offsets(ndim: int, connectivity: int):
    table = {
        (1, 2): [(1,), (-1,)],
        (2, 4): _OFF_2D_4,
        (2, 6): _OFF_2D_6,
        (3, 6): _OFF_3D_6,
        (3, 14): _OFF_3D_14,
        (3, 18): _OFF_3D_18,
        (3, 26): _OFF_3D_26,
    }
    key = (ndim, connectivity)
    if key not in table:
        raise ValueError(f"unsupported (ndim, connectivity)={key}")
    return table[key]


def shift_fill(a: jax.Array, off, fill) -> jax.Array:
    """result[p] = a[p + off], `fill` outside the domain."""
    pads = [(max(-o, 0), max(o, 0)) for o in off]
    padded = jnp.pad(a, pads, constant_values=fill)
    slices = tuple(
        slice(max(o, 0), max(o, 0) + s) for o, s in zip(off, a.shape)
    )
    return padded[slices]


# --- structured grids -------------------------------------------------------


def grid_steepest(order: jax.Array, connectivity: int = 6,
                  descending: bool = True, id_offset=0) -> jax.Array:
    """Pointer init on a structured grid.

    Args:
      order: integer order field (any shape, unique values).
      id_offset: added to the returned flat indices (used by the distributed
        slab decomposition to emit *global* ids from a local block).

    Returns flat pointer array of `order.size` int32 (self for local extrema).
    """
    key = order if descending else (-order)
    n = order.size
    dtype = jnp.int32 if n < 2**31 else jnp.int64
    idx = (jnp.arange(n, dtype=dtype) + id_offset).reshape(order.shape)
    fill_key = jnp.iinfo(key.dtype).min
    # Stacked candidates + one argmax instead of a chain of per-offset
    # selects: the chained-where form sends XLA:CPU fusion into minutes-long
    # compiles at connectivity 14 (and pathologically so under vmap — the
    # batched serving path).  Self is candidate 0, so the first-max-wins tie
    # rule of argmax matches the strict-> chain: real order values are unique
    # (permutation precondition), and the only repeatable value is the pad
    # sentinel -1, where self wins in both forms.
    offs = neighbor_offsets(order.ndim, connectivity)
    cand_val = jnp.stack([key] + [shift_fill(key, off, fill_key)
                                  for off in offs])
    cand_idx = jnp.stack([idx] + [shift_fill(idx, off, dtype(-1))
                                  for off in offs])
    choice = jnp.argmax(cand_val, axis=0)
    return jnp.take_along_axis(cand_idx, choice[None], axis=0)[0].ravel()


def grid_mask_argmax(mask: jax.Array, connectivity: int = 6,
                     id_offset=0) -> jax.Array:
    """Pointer init for connected components (Alg. 3 line 6): largest masked
    neighbor id (including self); -1 for unmasked vertices."""
    n = mask.size
    dtype = jnp.int32 if n < 2**31 else jnp.int64
    idx = (jnp.arange(n, dtype=dtype) + id_offset).reshape(mask.shape)
    key = jnp.where(mask, idx, dtype(-1))
    best = key
    for off in neighbor_offsets(mask.ndim, connectivity):
        cand = shift_fill(key, off, dtype(-1))
        best = jnp.maximum(best, cand)
    return jnp.where(mask, best, dtype(-1)).ravel()


# --- unstructured graphs ----------------------------------------------------


def graph_steepest(order: jax.Array, senders: jax.Array, receivers: jax.Array,
                   descending: bool = True) -> jax.Array:
    """Pointer init on an edge-list graph (directed edges sender->receiver;
    pass both directions for undirected meshes).

    order must be a permutation of [0, n) so that the max order value can be
    inverted back to a vertex id.
    """
    n = order.shape[0]
    key = order if descending else (n - 1 - order)
    inv = inverse_permutation(key)
    neigh_max = jax.ops.segment_max(
        key[receivers], senders, num_segments=n, indices_are_sorted=False
    )
    neigh_max = jnp.maximum(neigh_max, key)  # include self; fixes -inf/empty
    return jnp.where(neigh_max > key, inv[neigh_max], jnp.arange(n, dtype=jnp.int32))


def graph_mask_argmax(mask: jax.Array, senders: jax.Array,
                      receivers: jax.Array,
                      ghost: jax.Array | None = None) -> jax.Array:
    """CC pointer init on an edge-list graph; -1 for unmasked vertices.
    Edges incident to unmasked vertices are ignored (paper Alg. 3).

    `ghost` (optional bool array) marks one-ring ghost vertices of a
    distributed vertex partition: masked ghosts pretend to be roots (point
    to themselves) exactly like the ghost layer of the structured backend
    (paper Alg. 1 lines 6-8) — their true pointer is resolved later through
    the gathered boundary table.  Owned vertices may still point *at* a
    ghost, which is what carries cross-partition chains into the table.
    """
    n = mask.shape[0]
    ids = jnp.arange(n, dtype=jnp.int32)
    key = jnp.where(mask, ids, -1)
    edge_val = jnp.where(mask[senders] & mask[receivers], key[receivers], -1)
    neigh = jax.ops.segment_max(edge_val, senders, num_segments=n)
    best = jnp.maximum(jnp.maximum(neigh, key), -1)
    out = jnp.where(mask, best, -1)
    if ghost is not None:
        out = jnp.where(ghost & mask, ids, out)
    return out
