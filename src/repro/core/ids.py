"""Id / order-field utilities shared by all DPC variants.

The paper (§3.1, §4.1) requires an injective scalar field, enforced by a
Simulation-of-Simplicity variant: globally sort vertices by (scalar, global
id) and use the sort rank as the *order field*.  All DPC code operates on
this integer order field, never on raw scalars.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def compute_order(scalars: jax.Array, ids: jax.Array | None = None) -> jax.Array:
    """Global order field: rank of each vertex under (scalar, id) lexsort.

    Mirrors TTK's ttkArrayPreconditioning (paper §4.1).  Returns int32 ranks
    in [0, N) — a permutation, hence injective.
    """
    flat = scalars.ravel()
    n = flat.shape[0]
    if ids is None:
        ids = jnp.arange(n, dtype=jnp.int32)
    else:
        ids = ids.ravel()
    perm = jnp.lexsort((ids, flat))  # stable: primary scalar, tie-break id
    order = jnp.zeros(n, dtype=jnp.int32).at[perm].set(
        jnp.arange(n, dtype=jnp.int32)
    )
    return order.reshape(scalars.shape)


def inverse_permutation(perm: jax.Array) -> jax.Array:
    """inv[perm[i]] = i.  Used to map max-order values back to vertex ids."""
    n = perm.shape[0]
    return jnp.zeros(n, dtype=perm.dtype).at[perm.ravel()].set(
        jnp.arange(n, dtype=perm.dtype)
    )


def flat_ids(shape, dtype=jnp.int32) -> jax.Array:
    """Row-major flat id grid for a structured grid of `shape`."""
    n = int(np.prod(shape))
    return jnp.arange(n, dtype=dtype).reshape(shape)


def compact_labels(labels: jax.Array, fill_value: int = -1):
    """Relabel arbitrary label values to [0, k).  Not jit-shape-stable in k;
    returns (compact, k).  Negative labels (unmasked) keep `fill_value`."""
    flat = labels.ravel()
    uniq = jnp.unique(flat, size=flat.shape[0], fill_value=jnp.iinfo(flat.dtype).max)
    idx = jnp.searchsorted(uniq, flat)
    neg = jnp.searchsorted(uniq, 0)  # number of negative labels
    compact = jnp.where(flat < 0, fill_value, idx - neg)
    k = int((uniq != jnp.iinfo(flat.dtype).max).sum() - int(neg))
    return compact.reshape(labels.shape), k
