"""Ragged pad-and-mask decomposition (deviation (p) in DESIGN.md), locked
down by the property-based oracle harness.

Arbitrary grid extents (prime, non-divisible, smaller than the layout) and
imbalanced graph partitions (METIS stand-in random assignments, empty and
single-vertex partitions) must produce labels bit-identical to the
single-device oracles, with exactly one communication phase.  The case
generators live in `tests/oracles.py` as deterministic functions of a seed:
the fast CI job runs the fixed seed corpus; when hypothesis is installed a
slow-marked property test draws extra seeds through the same generators.

Distributed checks run in subprocesses with 8 virtualized host devices (the
dry-run rule: never set the device-count flag globally); the worker takes
its seed list as JSON argv so corpus and hypothesis runs share one script.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from oracles import (GRID_SEED_CORPUS, GRAPH_SEED_CORPUS, HAVE_HYPOTHESIS,
                     ragged_grid_case, ragged_graph_case)

_ROOT = os.path.join(os.path.dirname(__file__), "..")

if HAVE_HYPOTHESIS:
    from hypothesis import given, settings, strategies as st


def _run_worker(script, args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), os.path.dirname(__file__)])
    proc = subprocess.run([sys.executable, "-c", script] + args, env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


# --- regression: the old divisibility / balance ValueErrors are gone ---------


def test_blockdecomp_accepts_nondivisible():
    """grid % layout != 0 decomposes via ceil-division + padding instead of
    raising (the paper's real dataset shapes are never multiples)."""
    from repro.core.distributed import BlockDecomp
    d = BlockDecomp((97, 61, 43), (2, 2, 2), ("bx", "by", "bz"))
    assert d.local == (49, 31, 22)
    assert d.padded == (98, 62, 44)
    assert d.ragged
    assert 0 < d.pad_fraction < 1
    assert 0 < d.n_valid_slots < d.table_size
    # grid smaller than the layout: trailing blocks are entirely padding
    d = BlockDecomp((3, 9), (8,), ("bx",))
    assert d.local[0] == 1 and d.padded[0] == 8
    # divisible grids keep the exact (non-padded) geometry
    d = BlockDecomp((8, 8, 8), (2, 2, 2), ("bx", "by", "bz"))
    assert not d.ragged and d.pad_fraction == 0.0
    assert d.n_valid_slots == d.table_size


def test_graphdecomp_accepts_imbalanced():
    """The balanced-counts ValueError is unreachable by design now: any
    `part=` assignment (future METIS) pads the owned set to max(counts)."""
    from repro.core.distributed_graph import GraphDecomp
    s = np.array([0, 1, 2, 3, 4, 5, 6])
    r = np.array([1, 2, 3, 4, 5, 6, 7])
    ss, rr = np.concatenate([s, r]), np.concatenate([r, s])
    # counts [5, 3] — the case the old error path rejected
    g = GraphDecomp(8, ss, rr, 2, part=[0, 0, 0, 0, 0, 1, 1, 1])
    assert g.owned_counts.tolist() == [5, 3]
    assert g.n_owned == 5 and g.pad_fraction > 0
    # a single-vertex partition
    g = GraphDecomp(8, ss, rr, 2, part=[0, 0, 0, 0, 0, 0, 0, 1])
    assert g.owned_counts.tolist() == [7, 1]
    # an empty partition
    g = GraphDecomp(8, ss, rr, 3, part=[0, 0, 0, 0, 1, 1, 1, 1])
    assert g.owned_counts.tolist() == [4, 4, 0]
    # non-divisible default contiguous partition (no rounding of n)
    g = GraphDecomp(7, [], [], 3)
    assert g.owned_counts.tolist() == [3, 2, 2]


def test_graphdecomp_still_validates_part_range():
    from repro.core.distributed_graph import GraphDecomp
    with pytest.raises(ValueError, match="part values"):
        GraphDecomp(4, [0, 1], [1, 0], 2, part=[0, 1, 2, 0])
    with pytest.raises(ValueError, match="every vertex"):
        GraphDecomp(4, [0, 1], [1, 0], 2, part=[0, 1])


# --- decomposition geometry invariants (in-process, no devices needed) ------
# run under the hypothesis strategies when installed, else on the corpus


def _check_block_invariants(case):
    from repro.core.distributed import BlockDecomp
    shape, layout, conn, mask_p = case
    dec = BlockDecomp(shape, layout, ("bx", "by", "bz")[:len(layout)])
    assert all(p >= g for p, g in zip(dec.padded, dec.grid))
    for a in range(dec.k):
        assert dec.local[a] * dec.layout[a] == dec.padded[a]
    assert dec.ragged == (dec.padded != dec.grid)
    assert (dec.pad_fraction > 0) == dec.ragged
    assert 0 <= dec.n_valid_slots <= dec.table_size
    # the closed-form valid-slot count matches slot enumeration, and
    # boundary_pos round-trips every in-domain slot to a slot holding the
    # same vertex (corners canonicalise across axes but never move)
    coords = dec.slot_coords(np)
    indomain = (coords < np.asarray(dec.grid)).all(axis=1)
    assert dec.n_valid_slots == int(indomain.sum())
    g = (coords[indomain].astype(np.int64)
         * np.asarray(dec.stride, np.int64)).sum(axis=1)
    is_b, pos = dec.boundary_pos(g, np)
    assert is_b.all()
    assert (np.asarray(coords)[pos] == np.asarray(coords)[indomain]).all()


def _check_graph_invariants(case):
    from repro.core.distributed_graph import GraphDecomp
    n, s, r, nparts, part, mask = case
    dec = GraphDecomp(n, s, r, nparts, part=part)
    counts = np.bincount(part, minlength=nparts)
    assert dec.n_owned == int(counts.max())
    assert dec.owned_counts.tolist() == counts.tolist()
    # every vertex owned exactly once; pad slots carry the sentinel gid n
    real = dec.owned_gid[dec.owned_gid < n]
    assert np.sort(real).tolist() == list(range(n))
    assert (dec.owned_gid[dec.owned_gid >= n] == n).all()
    assert dec.n_cut == dec.cut_gid_sorted.size
    assert dec.table_size == dec.nparts * dec.c_max
    # pad owned slots point at invalid local slots (mask False downstream)
    for p in range(nparts):
        pads = dec.owned_lidx[p, counts[p]:]
        assert (~dec.local_valid[p][pads]).all()


if HAVE_HYPOTHESIS:
    from oracles import grid_case_strategy, graph_case_strategy

    @given(grid_case_strategy())
    @settings(max_examples=100, deadline=None)
    def test_blockdecomp_geometry_invariants(case):
        _check_block_invariants(case)

    @given(graph_case_strategy())
    @settings(max_examples=100, deadline=None)
    def test_graphdecomp_geometry_invariants(case):
        _check_graph_invariants(case)
else:
    @pytest.mark.parametrize("seed", GRID_SEED_CORPUS + tuple(
        100 + s for s in range(24)))
    def test_blockdecomp_geometry_invariants(seed):
        _check_block_invariants(ragged_grid_case(seed))

    @pytest.mark.parametrize("seed", GRAPH_SEED_CORPUS + tuple(
        100 + s for s in range(24)))
    def test_graphdecomp_geometry_invariants(seed):
        _check_graph_invariants(ragged_graph_case(seed))


# --- the distributed-vs-oracle harness (8 virtualized devices) ---------------

_GRID_WORKER = textwrap.dedent("""
    import json, os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components, compute_order)
    import oracles

    assert len(jax.devices()) == 8
    seeds = json.loads(sys.argv[1])
    failures = []
    for seed in seeds:
        shape, layout, conn, mask_p = oracles.ragged_grid_case(seed)
        rng = np.random.default_rng(seed)
        mesh = make_dpc_mesh(layout)
        tag = (seed, shape, layout, conn)

        order = compute_order(jnp.asarray(rng.standard_normal(shape)))
        desc = bool(seed % 2 == 0)   # alternate manifold directions
        got, st = distributed_manifold(order, mesh, conn, desc)
        ref = oracles.oracle_manifold(np.asarray(order), conn, desc)
        if got.shape != shape:
            failures.append(("man-shape", tag))
        if not (np.asarray(got).ravel() == ref.ravel()).all():
            failures.append(("manifold", tag))
        if int(st.comm_phases) != 1:
            failures.append(("man-comm", tag, int(st.comm_phases)))

        mask = rng.random(shape) < mask_p
        got, st = distributed_connected_components(jnp.asarray(mask), mesh,
                                                   conn)
        ref = oracles.oracle_components(mask, conn)
        if not (np.asarray(got) == ref).all():
            failures.append(("cc", tag, mask_p))
        if int(st.comm_phases) != 1:
            failures.append(("cc-comm", tag, int(st.comm_phases)))
        if seed % 3 == 0:
            # §Perf variant stays bit-identical under padding (every third
            # seed: one extra compile per case is the harness' main cost)
            alt, st2 = distributed_connected_components(
                jnp.asarray(mask), mesh, conn, gather_mask=False)
            if not (np.asarray(alt) == ref).all():
                failures.append(("cc-nomask", tag))
            if float(st2.ghost_bytes) >= float(st.ghost_bytes):
                failures.append(("cc-bytes", tag))

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("RAGGED-GRID-OK")
""")

_ACCEPTANCE_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components,
                            descending_manifold, ascending_manifold,
                            connected_components_grid, compute_order)

    assert len(jax.devices()) == 8
    shape, layout = (97, 61, 43), (2, 2, 2)
    rng = np.random.default_rng(97)
    order = compute_order(jnp.asarray(rng.standard_normal(shape)))
    mesh = make_dpc_mesh(layout)
    failures = []

    for desc in (True, False):
        got, st = distributed_manifold(order, mesh, 6, desc)
        ref, _ = (descending_manifold if desc else ascending_manifold)(
            order, 6)
        if not (np.asarray(got).ravel() == np.asarray(ref).ravel()).all():
            failures.append(("manifold", desc))
        if int(st.comm_phases) != 1:
            failures.append(("man-comm", desc, int(st.comm_phases)))
        if not 0 < float(st.pad_fraction) < 1:
            failures.append(("pad_fraction", float(st.pad_fraction)))

    mask = jnp.asarray(rng.random(shape) < 0.6)
    ref = connected_components_grid(mask, 6)
    for gather_mask in (True, False):
        got, st = distributed_connected_components(
            mask, mesh, 6, gather_mask=gather_mask)
        if not (np.asarray(got) == np.asarray(ref.labels)).all():
            failures.append(("cc", gather_mask))
        if int(st.comm_phases) != 1:
            failures.append(("cc-comm", gather_mask, int(st.comm_phases)))

    # the full Freudenthal stencil across ragged diagonal cuts
    got, _ = distributed_manifold(order, mesh, 14, True)
    ref14, _ = descending_manifold(order, 14)
    if not (np.asarray(got).ravel() == np.asarray(ref14).ravel()).all():
        failures.append(("manifold-14",))

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("RAGGED-97x61x43-OK")
""")

_GRAPH_WORKER = textwrap.dedent("""
    import json, os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (GraphDecomp,
                            distributed_connected_components_graph,
                            make_dpc_mesh)
    import oracles

    assert len(jax.devices()) == 8
    seeds = json.loads(sys.argv[1])
    failures = []

    def check(n, s, r, mask, nparts, part, tag):
        dec = GraphDecomp(n, s, r, nparts, part=part)
        mesh = make_dpc_mesh(nparts)
        got, st = distributed_connected_components_graph(
            jnp.asarray(mask), dec, mesh)
        ref = oracles.oracle_components_graph(mask, s, r)
        if not (np.asarray(got) == ref).all():
            failures.append(("labels", tag))
        want_comm = 1 if dec.table_size else 0
        if int(st.comm_phases) != want_comm:
            failures.append(("comm", tag, int(st.comm_phases)))
        return st

    for seed in seeds:
        n, s, r, nparts, part, mask = oracles.ragged_graph_case(seed)
        check(n, s, r, mask, nparts, part, ("corpus", seed, n, nparts))

    # acceptance: 1000 vertices over 8 imbalanced partitions, one phase
    rng = np.random.default_rng(1000)
    a = rng.integers(0, 1000, 3000)
    b = rng.integers(0, 1000, 3000)
    s = np.concatenate([a, b]); r = np.concatenate([b, a])
    part = rng.integers(0, 8, 1000)
    st = check(1000, s, r, rng.random(1000) < 0.6, 8, part, ("1000v",))
    if int(st.comm_phases) != 1:
        failures.append(("1000v-comm", int(st.comm_phases)))
    if not float(st.pad_fraction) > 0:
        failures.append(("1000v-pad", float(st.pad_fraction)))
    # non-divisible default contiguous partition (part=None)
    st = check(1000, s, r, rng.random(1000) < 0.5, 3, None, ("contig-3",))

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("RAGGED-GRAPH-OK")
""")


def test_ragged_grid_matches_oracles():
    """Seed-corpus property harness: distributed labels on random ragged
    grids/layouts are bit-identical to the pure-numpy oracles (fast CI)."""
    out = _run_worker(_GRID_WORKER, [json.dumps(list(GRID_SEED_CORPUS))])
    assert "RAGGED-GRID-OK" in out


def test_ragged_acceptance_97x61x43():
    """The acceptance case: a 97x61x43 grid over layout (2, 2, 2) is
    bit-identical to the single-device oracles with comm_phases == 1."""
    out = _run_worker(_ACCEPTANCE_WORKER, [])
    assert "RAGGED-97x61x43-OK" in out


def test_ragged_graph_matches_oracles():
    """Seed-corpus property harness for imbalanced vertex partitions, plus
    the 1000-vertex / 8-imbalanced-partitions acceptance case."""
    out = _run_worker(_GRAPH_WORKER, [json.dumps(list(GRAPH_SEED_CORPUS))])
    assert "RAGGED-GRAPH-OK" in out


if HAVE_HYPOTHESIS:
    # extra seeds through the same generators; slow-marked so the fast CI
    # job stays on the deterministic corpus
    @pytest.mark.slow
    @given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=4,
                    unique=True))
    @settings(max_examples=5, deadline=None)
    def test_property_ragged_grid(seeds):
        out = _run_worker(_GRID_WORKER, [json.dumps(seeds)])
        assert "RAGGED-GRID-OK" in out

    @pytest.mark.slow
    @given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=4,
                    unique=True))
    @settings(max_examples=5, deadline=None)
    def test_property_ragged_graph(seeds):
        out = _run_worker(_GRAPH_WORKER, [json.dumps(seeds)])
        assert "RAGGED-GRAPH-OK" in out
