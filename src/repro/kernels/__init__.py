"""Pallas TPU kernels for the perf-critical hot spots, with jnp oracles.

  steepest_neighbor  — DPC init stencil (Alg. 1 l. 3-5), VMEM-tiled argmax
  fused_local_phase  — init + in-tile doubling saturation in ONE kernel
                       (the block-local phase of Alg. 1/3; DESIGN.md §Perf)
  block_pathcompress — K in-VMEM doubling rounds (thread-local compression)
  flash_attention    — fused online-softmax attention for the LM substrate
  segment_bag        — fused EmbeddingBag (vocab-tiled gather+reduce),
                       the recsys lookup hot path
"""
from . import ops, ref
from .steepest_neighbor import steepest_neighbor
from .fused_local_phase import fused_local_phase
from .block_pathcompress import block_pathcompress
from .flash_attention import flash_attention
from .segment_bag import segment_bag
