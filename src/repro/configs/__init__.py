"""Architecture registry: one module per assigned arch (+ the paper's own
DPC grid workload).  Each module exposes FAMILY, full_config(),
smoke_config() and SHAPES."""
from importlib import import_module

ARCH_IDS = [
    # LM family (5)
    "stablelm_12b", "llama3_2_1b", "minitron_8b", "deepseek_moe_16b",
    "kimi_k2_1t",
    # GNN (4)
    "gat_cora", "schnet", "meshgraphnet", "dimenet",
    # RecSys (1)
    "bst",
    # the paper's own workloads (structured grid + unstructured graph)
    "dpc_grid",
    "dpc_graph",
]

_ALIAS = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIAS.update({"llama3.2-1b": "llama3_2_1b", "kimi-k2-1t-a32b": "kimi_k2_1t",
               "stablelm-12b": "stablelm_12b", "minitron-8b": "minitron_8b",
               "deepseek-moe-16b": "deepseek_moe_16b",
               "gat-cora": "gat_cora",
               # serving-layer config (not an arch; lives outside ARCH_IDS)
               "serve-topology": "serve_topology"})


def get(arch_id: str):
    name = _ALIAS.get(arch_id, arch_id)
    return import_module(f"repro.configs.{name}")
