"""Worker for shard-scaling benchmarks: runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess.
Prints CSV rows:  name,us_per_call,derived

Covers 1-D slab layouts and 2-D/3-D block layouts at equal device counts,
so the strong/weak tables expose the surface-to-volume gain of the block
decomposition (ghost_bytes column)."""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import sys
import time

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import (compute_order, make_dpc_mesh, distributed_manifold,
                        distributed_connected_components)
from repro.configs.dpc_grid import SCALING_LAYOUTS
from repro.data import perlin_noise


def timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def main():
    mode = sys.argv[1]           # "strong" | "weak"
    base = int(sys.argv[2])      # grid edge length (strong) / per-block (weak)
    for layout in SCALING_LAYOUTS:
        pads = layout + (1,) * (3 - len(layout))
        if mode == "strong":
            dims = (base, base, base)
        else:  # weak scaling: volume grows with the block lattice
            dims = tuple(base * p for p in pads)
        field = perlin_noise(dims, frequency=0.1, seed=0)
        order = compute_order(jnp.asarray(field))
        mask = jnp.asarray(field > np.quantile(field, 0.9))
        mesh = make_dpc_mesh(layout)
        tag = "x".join(map(str, layout))

        tab = "tab1" if mode == "strong" else "tab2"
        us, (labels, stats) = timeit(
            lambda o: distributed_manifold(o, mesh, 6, True), order)
        print(f"{tab}_{mode}_seg_{base}_{tag}blocks,{us:.0f},"
              f"ghost_bytes={int(stats.ghost_bytes)};"
              f"local_iters={int(stats.local_iters)};"
              f"table_iters={int(stats.table_iters)}", flush=True)

        us, (labels, stats) = timeit(
            lambda m: distributed_connected_components(m, mesh, 6), mask)
        print(f"{tab}_{mode}_cc_{base}_{tag}blocks,{us:.0f},"
              f"ghost_bytes={int(stats.ghost_bytes)};"
              f"masked_frac={float(stats.masked_ghost_fraction):.4f};"
              f"stitch_rounds={int(stats.stitch_rounds)}", flush=True)


if __name__ == "__main__":
    main()
