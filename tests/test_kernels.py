"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
sweeping shapes and dtypes as the deliverable requires."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.steepest_neighbor import steepest_neighbor
from repro.kernels.block_pathcompress import block_pathcompress
from repro.kernels.flash_attention import flash_attention
from repro.core.steepest import neighbor_offsets, grid_steepest


# --- steepest_neighbor -------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 8, 8), (4, 16, 8),
                                   (32, 4, 4), (8, 5, 7)])
@pytest.mark.parametrize("conn", [6, 14])
def test_steepest_kernel_vs_ref(shape, conn):
    rng = np.random.default_rng(hash((shape, conn)) % 2**31)
    order = jnp.asarray(rng.permutation(int(np.prod(shape))).reshape(shape)
                        .astype(np.int32))
    got = steepest_neighbor(order, conn, block_x=4, interpret=True)
    want = ref.steepest_neighbor_ref(order, neighbor_offsets(3, conn))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_steepest_kernel_vs_core():
    """Kernel == the core library path used by DPC."""
    rng = np.random.default_rng(0)
    order = jnp.asarray(rng.permutation(8 * 8 * 8).reshape(8, 8, 8)
                        .astype(np.int32))
    got = steepest_neighbor(order, 6, block_x=2, interpret=True)
    want = grid_steepest(order, 6).reshape(order.shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_x", [1, 2, 8])
def test_steepest_kernel_blocking_invariance(block_x):
    rng = np.random.default_rng(1)
    order = jnp.asarray(rng.permutation(8 * 6 * 6).reshape(8, 6, 6)
                        .astype(np.int32))
    got = steepest_neighbor(order, 6, block_x=block_x, interpret=True)
    want = ref.steepest_neighbor_ref(order, neighbor_offsets(3, 6))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- block_pathcompress ------------------------------------------------------


@pytest.mark.parametrize("n,block", [(64, 16), (256, 64), (1024, 1024),
                                     (128, 32),
                                     # ragged last tile (pad-and-mask,
                                     # deviation (p) in DESIGN.md)
                                     (100, 32), (97, 64), (130, 128)])
@pytest.mark.parametrize("rounds", [1, 3, 6])
def test_block_pathcompress_vs_ref(n, block, rounds):
    rng = np.random.default_rng(n + rounds)
    d = np.arange(n)
    for v in range(n - 1):
        if rng.random() < 0.85:
            d[v] = rng.integers(v + 1, n)
    d[rng.random(n) < 0.05] = -1
    d = jnp.asarray(d, dtype=jnp.int32)
    got = block_pathcompress(d, rounds=rounds, block=block, interpret=True)
    # per-block oracle
    want = jnp.concatenate([
        ref.block_pathcompress_ref(d[i:i + block], rounds, base=i)
        for i in range(0, n, block)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_pathcompress_then_global_converges():
    """Block rounds + global rounds give the same fixpoint as global-only
    (the correctness argument for the TPU schedule)."""
    from repro.core import path_compress
    rng = np.random.default_rng(3)
    n = 512
    d = np.arange(n)
    for v in range(n - 1):
        if rng.random() < 0.9:
            d[v] = rng.integers(v + 1, n)
    d = jnp.asarray(d, dtype=jnp.int32)
    pre = block_pathcompress(d, rounds=4, block=64, interpret=True)
    out_hybrid, it_hybrid = path_compress(pre)
    out_global, it_global = path_compress(d)
    np.testing.assert_array_equal(np.asarray(out_hybrid),
                                  np.asarray(out_global))


# --- flash attention ---------------------------------------------------------


@pytest.mark.parametrize("b,h,hkv,sq,sk,dh", [
    (1, 4, 4, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),    # GQA group 2
    (1, 8, 1, 128, 128, 128),   # MQA
    (2, 2, 2, 256, 128, 32),    # cross (kv shorter)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_mha(b, h, hkv, sq, sk, dh, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, h, sq, dh), dtype)
    k = jax.random.normal(k2, (b, hkv, sk, dh), dtype)
    v = jax.random.normal(k3, (b, hkv, sk, dh), dtype)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,sk", [(128, 128), (128, 384), (256, 256)])
def test_flash_causal(sq, sk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 4, sq, 64))
    k = jax.random.normal(k2, (1, 2, sk, 64))
    v = jax.random.normal(k3, (1, 2, sk, 64))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_ref_matches_mha_chunked():
    """The model-side chunked implementation == unfused reference."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (2, 8, 64, 32))
    k = jax.random.normal(k2, (2, 2, 192, 32))
    v = jax.random.normal(k3, (2, 2, 192, 32))
    got = ref.flash_attention_ref(q, k, v, causal=True, block_kv=64)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --- segment_bag (EmbeddingBag) ----------------------------------------------


@pytest.mark.parametrize("v,d,b,l,vb,bb", [
    (64, 8, 16, 5, 16, 8),
    (256, 32, 32, 16, 64, 32),
    (100, 16, 24, 4, 100, 24),   # single tile
    (512, 4, 8, 3, 128, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_bag_vs_embedding_bag(v, d, b, l, vb, bb, dtype):
    from repro.kernels.segment_bag import segment_bag
    from repro.models.bst import embedding_bag
    key = jax.random.PRNGKey(v + b)
    table = jax.random.normal(key, (v, d), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (b, l), -1, v)
    got = segment_bag(table, ids, vocab_block=vb, batch_block=bb,
                      interpret=True)
    # oracle in f32 (the kernel accumulates f32; bf16 ref sums reorder)
    want = embedding_bag(table.astype(jnp.float32), ids).astype(dtype)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_segment_bag_all_padding():
    from repro.kernels.segment_bag import segment_bag
    table = jnp.ones((32, 4))
    ids = jnp.full((8, 3), -1)
    got = segment_bag(table, ids, vocab_block=16, batch_block=8,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0.0)
