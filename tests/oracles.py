"""Pure-numpy oracles for the DPC core (brute-force reference semantics),
plus the shared ragged-case generators used by the property-based harness
(`test_ragged_decomp.py`): random grid shapes including prime extents,
random layouts up to 8 devices, random feature masks, and random imbalanced
`part=` assignments.  Every case is a deterministic function of a single
integer seed, so the same generators serve as hypothesis strategies (seed
drawn by hypothesis, when installed) and as the fixed seed corpus that
keeps the fast CI job fast (`GRID_SEED_CORPUS` / `GRAPH_SEED_CORPUS`)."""
from __future__ import annotations

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.steepest import neighbor_offsets  # noqa: E402


def grid_neighbors(shape, connectivity):
    """Yield (flat_v, flat_u) directed neighbor pairs of a structured grid."""
    offs = neighbor_offsets(len(shape), connectivity)
    idx = np.arange(int(np.prod(shape))).reshape(shape)
    pairs = []
    for off in offs:
        src_sl, dst_sl = [], []
        for o, s in zip(off, shape):
            if o >= 0:
                src_sl.append(slice(0, s - o))
                dst_sl.append(slice(o, s))
            else:
                src_sl.append(slice(-o, s))
                dst_sl.append(slice(0, s + o))
        pairs.append((idx[tuple(src_sl)].ravel(), idx[tuple(dst_sl)].ravel()))
    send = np.concatenate([p[0] for p in pairs])
    recv = np.concatenate([p[1] for p in pairs])
    return send, recv


def oracle_manifold(order, connectivity=6, descending=True):
    """Follow the steepest path vertex-by-vertex (paper §3.3 definition)."""
    shape = order.shape
    flat = order.ravel().astype(np.int64)
    n = flat.size
    send, recv = grid_neighbors(shape, connectivity)
    # adjacency list
    neigh = [[] for _ in range(n)]
    for s, r in zip(send, recv):
        neigh[s].append(r)
    key = flat if descending else -flat
    target = np.empty(n, dtype=np.int64)
    for v in range(n):
        best, bestk = v, key[v]
        for u in neigh[v]:
            if key[u] > bestk:
                best, bestk = u, key[u]
        target[v] = best
    # follow to fixpoint
    out = np.arange(n)
    for v in range(n):
        cur = v
        while target[cur] != cur:
            cur = target[cur]
        out[v] = cur
    return out.reshape(shape)


def oracle_components(mask, connectivity=6):
    """BFS connected components of the masked grid; label = max vertex id."""
    shape = mask.shape
    flat = mask.ravel().astype(bool)
    n = flat.size
    send, recv = grid_neighbors(shape, connectivity)
    neigh = [[] for _ in range(n)]
    for s, r in zip(send, recv):
        if flat[s] and flat[r]:
            neigh[s].append(r)
    labels = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    for v in range(n):
        if not flat[v] or seen[v]:
            continue
        stack, comp = [v], [v]
        seen[v] = True
        while stack:
            x = stack.pop()
            for u in neigh[x]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(u)
                    comp.append(u)
        m = max(comp)
        for u in comp:
            labels[u] = m
    return labels.reshape(shape)


# --- ragged pad-and-mask case generators (deviation (p) in DESIGN.md) -------

# deterministic corpus for the fast CI job (hypothesis, when installed,
# draws extra seeds through the same generators); sized so the subprocess
# compile time stays within the fast-suite budget
GRID_SEED_CORPUS = tuple(range(8))
GRAPH_SEED_CORPUS = tuple(range(8))


def ragged_grid_case(seed):
    """(shape, layout, connectivity, mask_p): a random 2-D/3-D grid with
    arbitrary (often prime, often non-divisible) extents and a random block
    layout of at most 8 devices; deterministic in `seed`."""
    rng = np.random.default_rng(0xD9C0 + seed)
    ndim = int(rng.integers(2, 4))
    shape = tuple(int(rng.choice([3, 4, 5, 6, 7, 9, 11, 13]))
                  for _ in range(ndim))
    k = int(rng.integers(1, ndim + 1))
    layout, budget = [], 8
    for _ in range(k):
        p = int(rng.choice([q for q in (1, 2, 3, 4, 5, 7, 8)
                            if q <= budget]))
        layout.append(p)
        budget //= p
    layout = tuple(layout)
    conn = int(rng.choice([4, 6] if ndim == 2 else [6, 14]))
    mask_p = float(rng.uniform(0.25, 0.95))
    return shape, layout, conn, mask_p


def ragged_graph_case(seed):
    """(n, senders, receivers, nparts, part, mask): a random sparse
    multigraph (both edge directions present) under a random *imbalanced*
    partition assignment — the METIS stand-in; partitions may be empty or
    own a single vertex; deterministic in `seed`."""
    rng = np.random.default_rng(0x96AF0 + seed)
    n = int(rng.integers(2, 120))
    m = int(rng.integers(1, 4 * n))
    a = rng.integers(0, n, m)
    b = rng.integers(0, n, m)
    senders = np.concatenate([a, b])
    receivers = np.concatenate([b, a])
    nparts = int(rng.choice([2, 3, 4, 8]))
    part = rng.integers(0, nparts, n)
    mask = rng.random(n) < float(rng.uniform(0.3, 0.95))
    return n, senders, receivers, nparts, part, mask


try:  # hypothesis strategies over the same generators (optional dep)
    from hypothesis import strategies as _st

    HAVE_HYPOTHESIS = True

    def grid_case_strategy():
        return _st.integers(0, 2**31 - 1).map(ragged_grid_case)

    def graph_case_strategy():
        return _st.integers(0, 2**31 - 1).map(ragged_graph_case)
except ImportError:
    HAVE_HYPOTHESIS = False


def oracle_components_graph(mask, senders, receivers):
    n = len(mask)
    neigh = [[] for _ in range(n)]
    for s, r in zip(senders, receivers):
        if mask[s] and mask[r]:
            neigh[s].append(r)
            neigh[r].append(s)
    labels = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    for v in range(n):
        if not mask[v] or seen[v]:
            continue
        stack, comp = [v], [v]
        seen[v] = True
        while stack:
            x = stack.pop()
            for u in neigh[x]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(u)
                    comp.append(u)
        m = max(comp)
        for u in comp:
            labels[u] = m
    return labels
