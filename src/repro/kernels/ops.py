"""Jit'd public wrappers for the Pallas kernels.

On TPU the fused kernels run compiled (`interpret=False`); on CPU (this
container, and any unit-test environment) they execute in interpret mode and
are validated against the pure-jnp oracles in ref.py.  `impl="ref"` forces
the oracle — the dry-run lowers models with the ref implementations so the
HLO stays portable across backends.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import ref
from .steepest_neighbor import steepest_neighbor as _steepest_kernel
from .block_pathcompress import block_pathcompress as _bpc_kernel
from .flash_attention import flash_attention as _flash_kernel
from .segment_bag import segment_bag as _bag_kernel
from .fused_local_phase import (KERNEL_CONNECTIVITIES,
                                fused_local_phase as _fused_kernel)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _grid_kernel_ok(field, connectivity: int) -> bool:
    """The grid stencil kernels are 3-D x-slab programs; 2-D fields and
    connectivities outside the 3-D offset table take the jnp fallback."""
    return field.ndim == 3 and connectivity in KERNEL_CONNECTIVITIES


def steepest_neighbor(order, connectivity: int = 6, impl: str = "auto",
                      block_x: int = 8):
    if (impl == "ref" or not _grid_kernel_ok(order, connectivity)
            or (impl == "auto" and not _on_tpu())):
        from repro.core.steepest import grid_steepest
        return grid_steepest(order, connectivity).reshape(order.shape)
    return _steepest_kernel(order, connectivity, block_x=block_x,
                            interpret=not _on_tpu())


def fused_local_phase(field, connectivity: int = 6, mode: str = "manifold",
                      self_mask=None, impl: str = "auto", block_x: int = 8,
                      id_dtype=None):
    """Fused block-local phase: pointer init + in-tile saturation rounds.

    The hot-path dispatch used by `_manifold_block` / `_cc_block` and the
    pure grid entry points.  Returns ``(pointers, kernel_rounds)`` with the
    SAME final-label contract on every path: the pointer array has the same
    chase fixpoint as the plain init, so the global `path_compress` that
    follows converges to bit-identical labels — the kernel path just starts
    it near-converged (DESIGN.md §Perf).

    impl="auto": compiled kernel on TPU, jnp init elsewhere;
    impl="kernel": force the kernel (interpret mode off-TPU — tests/benches);
    impl="ref": force the jnp init (``kernel_rounds == 0``).
    2-D fields and unsupported connectivities always fall back.
    """
    if impl not in ("auto", "kernel", "ref"):
        raise ValueError(f"impl must be auto|kernel|ref, got {impl!r}")
    use_kernel = (impl != "ref" and _grid_kernel_ok(field, connectivity)
                  and (impl == "kernel" or _on_tpu()))
    if use_kernel:
        return _fused_kernel(field, connectivity, mode=mode,
                             self_mask=self_mask, block_x=block_x,
                             interpret=not _on_tpu(), id_dtype=id_dtype)
    from repro.core.steepest import grid_steepest, grid_mask_argmax
    if mode == "manifold":
        d0 = grid_steepest(field, connectivity)
    elif mode == "cc":
        d0 = grid_mask_argmax(field, connectivity)
    else:
        raise ValueError(f"mode must be 'manifold' or 'cc', got {mode!r}")
    if id_dtype is not None:
        d0 = d0.astype(id_dtype)
    if self_mask is not None:
        keep = self_mask.ravel()
        if mode == "cc":
            keep = keep & (field.ravel() != 0)
        ids = jnp.arange(field.size, dtype=d0.dtype)
        d0 = jnp.where(keep, ids, d0)
    return d0.reshape(field.shape), jnp.int32(0)


def block_pathcompress(d, rounds: int = 4, block: int = 4096,
                       impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.block_pathcompress_ref(d, rounds)  # block = whole array
    return _bpc_kernel(d, rounds=rounds, block=block,
                       interpret=not _on_tpu())


def flash_attention(q, k, v, causal: bool = False, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_kernel(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=not _on_tpu())


def embedding_bag(table, ids, impl: str = "auto", vocab_block: int = 2048,
                  batch_block: int = 256):
    """Fused EmbeddingBag.  The tiled kernel wins when batch*L sweeps a
    meaningful fraction of the table (train/bulk shapes); sparse-read
    serving keeps the XLA gather path."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        from repro.models.bst import embedding_bag as _ref_bag
        return _ref_bag(table, ids)
    return _bag_kernel(table, ids, vocab_block=vocab_block,
                       batch_block=batch_block, interpret=not _on_tpu())
