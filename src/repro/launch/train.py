"""End-to-end training launcher (example application driver).

  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --smoke \
      --steps 300 --batch 8 --seq 128

Runs the full production loop on whatever devices exist: config -> params
-> sharded train step -> fault-tolerant driver (periodic async checkpoints,
restart-on-failure, straggler monitor) -> metrics.  With --chaos it injects
a failure mid-run to demonstrate restore-and-resume."""
from __future__ import annotations

import argparse
import dataclasses
from functools import partial

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.optim import adamw, warmup_cosine
from repro.checkpoint import CheckpointManager
from repro.runtime.driver import TrainDriver
from repro.runtime.meshctx import use_mesh
from repro.data.tokens import TokenStream
from repro.launch.mesh import make_smoke_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--chaos", action="store_true",
                    help="inject a failure at 60%% progress (demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mod = configs.get(args.arch)
    assert mod.FAMILY == "lm", "train.py drives LM archs; see examples/"
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    if args.seq % cfg.loss_chunks:
        cfg = dataclasses.replace(cfg, loss_chunks=1)
    print(f"[train] {cfg.name}: {cfg.n_params():,} params "
          f"({cfg.n_active_params():,} active)")

    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    opt = adamw(warmup_cosine(args.lr, 20, args.steps), weight_decay=0.01)
    mesh = make_smoke_mesh()

    def step_fn(state, batch):
        p, o = state
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        (loss, metrics), grads = jax.value_and_grad(
            lm.loss_fn, has_aux=True)(p, b, cfg)
        p, o, om = opt.update(grads, o, p)
        return (p, o), {"loss": loss, **metrics, **om}

    jit_step = jax.jit(step_fn, donate_argnums=0)

    def make_data(start):
        return TokenStream(cfg.vocab, args.batch, args.seq, seed=args.seed,
                           start_step=start)

    chaos = {"armed": args.chaos}

    def injector(step):
        if chaos["armed"] and step == int(args.steps * 0.6):
            chaos["armed"] = False
            return True
        return False

    ckpt = CheckpointManager(args.ckpt_dir, keep_last=2)
    with use_mesh(mesh):
        driver = TrainDriver(
            step_fn=jit_step, init_state=(params, opt.init(params)),
            make_data=make_data, ckpt=ckpt, ckpt_every=args.ckpt_every,
            failure_injector=injector if args.chaos else None,
            log_every=max(args.steps // 20, 1))
        state, report = driver.run(args.steps)

    # final eval on fresh batches
    losses = []
    stream = make_data(10_000)
    for _ in range(4):
        b = {k: jnp.asarray(v) for k, v in next(stream).items()}
        losses.append(float(lm.loss_fn(state[0], b, cfg)[0]))
    print(f"[train] done: eval_loss={np.mean(losses):.4f} report={report}")
    return np.mean(losses), report


if __name__ == "__main__":
    main()
