"""Distributed graph CC (GraphDecomp + Alg. 3 + Alg. 2, table-driven) ==
single-device `connected_components_graph`, bit-identical, across vertex
partition counts {1, 2, 4, 8} — including masks that split/merge components
exactly on partition cuts, non-contiguous partitions, and the §Perf
gather_mask=False variant.  Runs in a subprocess with 8 virtualized host
devices (the dry-run rule: never set the device-count flag globally)."""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (GraphDecomp, distributed_connected_components_graph,
                            connected_components_graph, make_dpc_mesh)
    from repro.data import grid_edge_list

    assert len(jax.devices()) == 8
    failures = []

    def check(n, s, r, mask, nparts, part=None, tag="", gather_mask=True,
              expect_comm=None):
        dec = GraphDecomp(n, s, r, nparts, part=part)
        mesh = make_dpc_mesh(nparts)
        got, stats = distributed_connected_components_graph(
            jnp.asarray(mask), dec, mesh, gather_mask=gather_mask)
        ref = connected_components_graph(
            jnp.asarray(mask), jnp.asarray(s), jnp.asarray(r))
        if not (np.asarray(got) == np.asarray(ref.labels)).all():
            failures.append(("labels", tag, nparts))
        # the paper's budget: at most ONE all_gather phase, exactly one
        # whenever there are inter-partition edges
        comm = int(stats.comm_phases)
        if expect_comm is None:
            expect_comm = 1 if dec.table_size else 0
        if comm != expect_comm:
            failures.append(("comm_phases", tag, nparts, comm))
        if dec.table_size and float(stats.ghost_bytes) <= 0:
            failures.append(("ghost_bytes", tag, nparts))
        return stats

    # --- synthetic tet-mesh-style edge list (Freudenthal tetrahedralization
    #     of a 4^3 grid, treated as a fully unstructured edge list) ---------
    s3, r3 = grid_edge_list((4, 4, 4), 14)
    rng = np.random.default_rng(0)
    for nparts in (1, 2, 4, 8):
        for p in (0.35, 0.8):
            check(64, s3, r3, rng.random(64) < p, nparts, tag=f"tet{p}")
        # pure geometry (paper: CC "computed on pure geometry without any
        # scalar data"): mask = all ones
        check(64, s3, r3, np.ones(64, bool), nparts, tag="tet-geom")

    # --- masks that split/merge components exactly on partition cuts ------
    # path graph 0-1-...-15, contiguous partitions of 4: cuts at 3|4, 7|8,
    # 11|12
    sp, rp = grid_edge_list((16,), 2)
    m = np.ones(16, bool)
    for nparts in (2, 4):
        check(16, sp, rp, m, nparts, tag="path-merge")        # spans all cuts
    cutsplit = np.ones(16, bool)
    cutsplit[[4, 8]] = False   # components end exactly at two cuts
    for nparts in (2, 4):
        check(16, sp, rp, cutsplit, nparts, tag="path-split")
    onecut = np.zeros(16, bool)
    onecut[3:5] = True         # a 2-vertex component straddling one cut
    check(16, sp, rp, onecut, 4, tag="path-straddle")

    # --- non-contiguous (table-driven) partition: strided assignment ------
    s2, r2 = grid_edge_list((8, 6), 6)
    part = (np.arange(48) % 4).astype(np.int64)
    for seed in (1, 2):
        mask = np.random.default_rng(seed).random(48) < 0.6
        check(48, s2, r2, mask, 4, part=part, tag="strided")

    # --- random multigraph (duplicate + self edges tolerated) -------------
    rng = np.random.default_rng(7)
    a = rng.integers(0, 40, 120)
    b = rng.integers(0, 40, 120)
    sr = np.concatenate([a, b]); rr = np.concatenate([b, a])
    check(40, sr, rr, rng.random(40) < 0.55, 8, tag="random")

    # --- §Perf variant: dropping the mask gather is bit-identical and
    #     strictly cheaper on the wire --------------------------------------
    mask = np.random.default_rng(9).random(64) < 0.6
    dec = GraphDecomp(64, s3, r3, 4)
    mesh = make_dpc_mesh(4)
    la, sa = distributed_connected_components_graph(
        jnp.asarray(mask), dec, mesh, gather_mask=True)
    lb, sb = distributed_connected_components_graph(
        jnp.asarray(mask), dec, mesh, gather_mask=False)
    if not (np.asarray(la) == np.asarray(lb)).all():
        failures.append(("gather_mask_variant",))
    if not float(sb.ghost_bytes) < float(sa.ghost_bytes):
        failures.append(("gather_mask_bytes",))
    if int(sb.comm_phases) != 1:
        failures.append(("gather_mask_comm", int(sb.comm_phases)))

    # stats sanity on a crossing mask
    st = check(64, s3, r3, np.ones(64, bool), 8, tag="stats")
    if not (0.0 < float(st.masked_ghost_fraction) <= 1.0):
        failures.append(("masked_fraction",))

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("GRAPH-OK")
""")


def test_distributed_graph_cc_matches_single_device():
    """Bit-identical labels vs the single-device oracle for partition counts
    {1, 2, 4, 8} with exactly one all_gather phase (fast CI job)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "GRAPH-OK" in proc.stdout
