"""Batched-serving launcher: prefill + decode loop with a KV cache.

  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.launch.mesh import make_smoke_mesh
from repro.runtime.meshctx import use_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    mod = configs.get(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg),
                     donate_argnums=1)

    with use_mesh(make_smoke_mesh()):
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out = [jnp.argmax(logits, -1)[:, None]]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, out[-1])
            out.append(jnp.argmax(logits, -1)[:, None])
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    toks = np.asarray(jnp.concatenate(out, axis=1))
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1e3:.1f}ms; decode {args.gen - 1} steps at "
          f"{tps:.1f} tok/s (incl. compile)")
    print("[serve] sample continuation ids:", toks[0][:12])
    assert np.isfinite(np.asarray(logits)).all()
    return tps


if __name__ == "__main__":
    main()
