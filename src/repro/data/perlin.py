"""Perlin noise (Perlin [39]) — the paper's synthetic scaling dataset:
"one layer of Perlin Noise with an amplitude of one and frequency in every
dimension of 0.1" (§5).  Gradient-lattice implementation in pure numpy/jnp so
the same field can be regenerated shard-locally at any resolution (weak
scaling) without materialising the global grid on one host.
"""
from __future__ import annotations

import numpy as np


def _fade(t):
    return t * t * t * (t * (t * 6 - 15) + 10)


def _gradients(rng: np.random.Generator, shape, ndim):
    g = rng.standard_normal(size=shape + (ndim,))
    g /= np.maximum(np.linalg.norm(g, axis=-1, keepdims=True), 1e-12)
    return g


def perlin_noise(shape, frequency: float = 0.1, seed: int = 0,
                 origin=None) -> np.ndarray:
    """N-D Perlin noise on an integer grid of `shape`, amplitude ~1.

    `origin` offsets the sample window in lattice units — shards evaluate
    their own slab with origin=(x0, 0, 0) and obtain bit-identical values to
    the global field (the lattice gradients are seeded by cell coordinate
    hashes, not by array position).
    """
    ndim = len(shape)
    origin = tuple(origin or (0,) * ndim)
    coords = np.meshgrid(*[
        (np.arange(s) + o) * frequency for s, o in zip(shape, origin)
    ], indexing="ij")
    pts = np.stack(coords, axis=-1)             # (*shape, ndim)
    cell = np.floor(pts).astype(np.int64)       # lattice cell of each point
    frac = pts - cell

    # hash lattice corners -> deterministic gradient, independent of window
    def corner_grad(corner_off):
        c = cell + np.array(corner_off)
        h = np.zeros(c.shape[:-1], dtype=np.uint64)
        for d in range(ndim):
            h = h * np.uint64(0x9E3779B97F4A7C15) + c[..., d].astype(np.uint64)
        h ^= h >> np.uint64(33)
        h *= np.uint64(0xFF51AFD7ED558CCD)
        h ^= h >> np.uint64(33)
        # map hash to a unit-ish gradient via ndim angles
        g = []
        hh = h.copy()
        for d in range(ndim):
            g.append(np.cos(2 * np.pi * (hh % np.uint64(65536)).astype(
                np.float64) / 65536.0 + d))
            hh = (hh >> np.uint64(16)) | (hh << np.uint64(48))
        g = np.stack(g, axis=-1)
        g /= np.maximum(np.linalg.norm(g, axis=-1, keepdims=True), 1e-12)
        return g

    corners = list(np.ndindex(*(2,) * ndim))
    u = _fade(frac)
    acc = None
    for corner in corners:
        grad = corner_grad(corner)
        disp = frac - np.array(corner)
        dot = np.sum(grad * disp, axis=-1)
        w = np.ones(dot.shape)
        for d in range(ndim):
            w = w * (u[..., d] if corner[d] else (1 - u[..., d]))
        acc = dot * w if acc is None else acc + dot * w

    # seed folds into the lattice origin so different seeds decorrelate
    if seed:
        return perlin_noise(shape, frequency, 0,
                            tuple(o + seed * 1009 for o in origin))
    return acc.astype(np.float32)
