"""Batched multi-tenant topology query engine (DESIGN.md §Serve).

`TopologyEngine.submit_batch` takes heterogeneous `TopologyRequest`s (mixed
shapes, mixed query kinds) and serves them through a handful of compiled
executables:

  expand   every request unbundles into uniform work items: an MS request
           becomes its two manifold directions, a threshold sweep becomes
           one CC item per threshold (the K masks come from ONE broadcast
           compare against the single field), ascending manifolds are
           flipped host-side so every manifold item runs the descending
           program (the trick `core.distributed` already uses);
  bucket   items group by padded layout — extents round up to the next
           power of two (`serve.bucketing`), so arbitrary request shapes
           collapse onto few layouts; graph items group by their mesh
           geometry (many masks / thresholds of one mesh batch together);
  execute  one vmapped (pure) or batched-`shard_map` (distributed) call per
           bucket chunk, so compilation AND the paper's single boundary
           all_gather amortise across tenants; compiled executables are
           cached per (layout, capacity) key with hit/miss counters;
  restore  labels slice back to each request's real extent and label VALUES
           remap from padded-shape flat ids to real-shape flat ids, which
           makes every engine result BIT-IDENTICAL to the sequential
           `repro.topology.submit` path (pinned by tests/test_serve_engine.py).

`EngineStats` aggregates requests/items/batches, executable-cache hits and
misses, and pad waste (real vs padded cells — the bounded-padding budget).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from ..core.connected_components import (connected_components_grid,
                                         connected_components_graph)
from ..core.ms_segmentation import descending_manifold
from ..core.steepest import graph_steepest
from ..core.pathcompress import path_compress
from ..core.distributed import (distributed_connected_components_batch,
                                distributed_manifold_batch)
from ..core.distributed_graph import (
    distributed_connected_components_graph_batch)
from ..topology import TopologyRequest, TopologyResult
from .bucketing import (bucket_shape, batch_capacity, pad_to,
                        remap_flat_labels, pad_waste)


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving counters (host-side, monotonically increasing)."""
    requests: int = 0
    items: int = 0          # work items after expansion (ms=2, sweep=K)
    batches: int = 0        # bucket-chunk executions
    cache_hits: int = 0     # executable reused for a bucket execution
    cache_misses: int = 0   # executable compiled for a new layout key
    real_cells: int = 0     # payload cells actually requested
    padded_cells: int = 0   # cells executed after layout + batch padding

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def pad_fraction(self) -> float:
        return (1.0 - self.real_cells / self.padded_cells
                if self.padded_cells else 0.0)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        d["pad_fraction"] = self.pad_fraction
        return d


@dataclasses.dataclass
class _WorkItem:
    """One uniform unit of work after request expansion."""
    kind: str               # "cc" | "manifold" (ms and sweeps are expanded)
    domain: str
    backend: str
    payload: np.ndarray     # real-extent mask (bool) / order field (int;
                            # ascending already flipped host-side)
    connectivity: int
    gather_mask: bool
    mesh: Any               # distributed only
    decomp: Any             # distributed graph only
    senders: Any            # graph only
    receivers: Any          # graph only
    req_idx: int
    role: tuple             # ("labels",) | ("desc",) | ("asc",) |
                            # ("sweep", k)


class TopologyEngine:
    """Batched serving front-end for `TopologyRequest`s.

    min_extent: smallest padded grid extent (bucket floor).
    max_batch:  largest batch capacity per execution; bucket occupancies
                beyond it run in chunks.
    """

    def __init__(self, min_extent: int = 8, max_batch: int = 64):
        self.min_extent = int(min_extent)
        self.max_batch = int(max_batch)
        self.stats = EngineStats()
        self._exec: dict = {}          # exec key -> (callable, has_stats)
        self._bucket_runs: dict = {}   # exec key -> executions served

    # --- public API -----------------------------------------------------------

    def submit(self, request: TopologyRequest) -> TopologyResult:
        return self.submit_batch([request])[0]

    def submit_batch(self, requests) -> list:
        """Serve a batch of requests; results keep submission order and are
        bit-identical to `repro.topology.submit` per request."""
        for r in requests:
            r.validate()
        items = []
        for idx, req in enumerate(requests):
            items.extend(self._expand(idx, req))
        self.stats.requests += len(requests)
        self.stats.items += len(items)

        buckets: dict = {}
        for it in items:
            buckets.setdefault(self._bucket_key(it), []).append(it)

        outputs: dict = {}   # (req_idx, role) -> (labels np, stats or None)
        for key, group in buckets.items():
            for lo in range(0, len(group), self.max_batch):
                self._run_bucket(key, group[lo:lo + self.max_batch], outputs)

        return [self._assemble(idx, req, outputs)
                for idx, req in enumerate(requests)]

    def cache_info(self) -> dict:
        return {"hits": self.stats.cache_hits,
                "misses": self.stats.cache_misses,
                "size": len(self._exec),
                "hit_rate": self.stats.hit_rate,
                "runs_per_executable": dict(self._bucket_runs)}

    # --- request expansion ----------------------------------------------------

    def _expand(self, idx: int, req: TopologyRequest) -> list:
        def item(kind, payload, role):
            return _WorkItem(kind=kind, domain=req.domain,
                             backend=req.backend,
                             payload=payload, connectivity=req.connectivity,
                             gather_mask=req.gather_mask, mesh=req.mesh,
                             decomp=req.decomp, senders=req.senders,
                             receivers=req.receivers, req_idx=idx, role=role)

        if req.query in ("manifold", "ms") and (
                req.domain == "graph" and req.backend == "distributed"):
            raise NotImplementedError(
                "manifold/MS on distributed graphs needs the order-field "
                "halo through GraphDecomp's ghost layer (ROADMAP carried "
                "item)")

        if req.query == "cc":
            return [item("cc", np.asarray(req.mask, dtype=bool),
                         ("labels",))]
        if req.query == "manifold":
            order = np.asarray(req.order)
            if not req.descending:
                order = np.asarray(order.size - 1 - order, dtype=order.dtype)
            return [item("manifold", order, ("labels",))]
        if req.query == "ms":
            order = np.asarray(req.order)
            flipped = np.asarray(order.size - 1 - order, dtype=order.dtype)
            return [item("manifold", order, ("desc",)),
                    item("manifold", flipped, ("asc",))]
        # threshold_sweep: K masks from ONE broadcast compare of the single
        # field; each enters the shared cc bucket of its layout
        field = np.asarray(req.field)
        thr = np.asarray(req.thresholds).reshape(-1)
        masks = field[None] > thr.reshape((-1,) + (1,) * field.ndim)
        return [item("cc", masks[k], ("sweep", k))
                for k in range(thr.size)]

    # --- bucketing / executables ----------------------------------------------

    def _bucket_key(self, it: _WorkItem) -> tuple:
        if it.domain == "grid":
            mesh_key = (None if it.backend == "pure"
                        else (tuple(it.mesh.axis_names),
                              tuple(it.mesh.devices.shape), id(it.mesh)))
            return ("grid", it.backend, it.kind, it.connectivity,
                    it.gather_mask,
                    bucket_shape(it.payload.shape, self.min_extent),
                    mesh_key)
        if it.backend == "pure":
            # same-geometry masks batch together; the compiled executable is
            # nonetheless shared across graphs of equal (n, m) because the
            # edge lists are traced arguments (see _exec_key)
            graph_key = (it.payload.shape[0], np.asarray(it.senders).size,
                         id(it.senders), id(it.receivers))
        else:
            graph_key = (id(it.decomp), it.gather_mask)
        return ("graph", it.backend, it.kind, graph_key)

    def _exec_key(self, bkey: tuple, it: _WorkItem, capacity: int) -> tuple:
        if bkey[0] == "graph" and bkey[1] == "pure":
            # drop the edge-list identity: (n, m) + dtypes determine the
            # trace, so equal-shape graphs share one executable
            bkey = bkey[:3] + ((it.payload.shape[0],
                                np.asarray(it.senders).size),)
        return bkey + (capacity, str(it.payload.dtype))

    def _build_executable(self, it: _WorkItem):
        """(callable, has_stats) for one layout bucket.  The callable takes
        the stacked padded payload (plus edge lists for pure graphs) and
        returns (labels, stats-or-None)."""
        conn, gm = it.connectivity, it.gather_mask
        if it.domain == "grid":
            if it.backend == "pure":
                if it.kind == "cc":
                    one = lambda m: connected_components_grid(m, conn).labels
                else:
                    one = lambda o: descending_manifold(o, conn)[0].reshape(
                        o.shape)
                return jax.jit(jax.vmap(one)), False
            mesh = it.mesh
            if it.kind == "cc":
                fn = lambda b: distributed_connected_components_batch(
                    b, mesh, conn, gm)
            else:
                fn = lambda b: distributed_manifold_batch(
                    b, mesh, conn, descending=True)
            return jax.jit(fn), True
        if it.backend == "pure":
            if it.kind == "cc":
                one = lambda m, s, r: connected_components_graph(
                    m, s, r).labels
            else:
                one = lambda o, s, r: path_compress(
                    graph_steepest(o, s, r, descending=True))[0]
            return jax.jit(jax.vmap(one, in_axes=(0, None, None))), False
        decomp, mesh = it.decomp, it.mesh
        fn = lambda b: distributed_connected_components_graph_batch(
            b, decomp, mesh, gm)
        return jax.jit(fn), True

    # --- execution ------------------------------------------------------------

    def _run_bucket(self, bkey: tuple, group: list, outputs: dict) -> None:
        it0 = group[0]
        capacity = batch_capacity(len(group), self.max_batch)
        ekey = self._exec_key(bkey, it0, capacity)
        if ekey in self._exec:
            self.stats.cache_hits += 1
        else:
            self.stats.cache_misses += 1
            self._exec[ekey] = self._build_executable(it0)
        self._bucket_runs[ekey] = self._bucket_runs.get(ekey, 0) + 1
        fn, has_stats = self._exec[ekey]
        self.stats.batches += 1

        if it0.domain == "grid":
            padded = bucket_shape(it0.payload.shape, self.min_extent)
            fill = False if it0.kind == "cc" else -1
            stack = np.stack(
                [pad_to(np.asarray(g.payload), padded, fill)
                 for g in group]
                + [np.full(padded, fill, dtype=it0.payload.dtype)]
                * (capacity - len(group)))
            real, padded_cells = pad_waste(
                [g.payload.shape for g in group], padded, capacity)
        else:
            padded = it0.payload.shape          # graphs never pad the extent
            fill = False if it0.kind == "cc" else -1
            stack = np.stack(
                [np.asarray(g.payload) for g in group]
                + [np.full(padded, fill, dtype=it0.payload.dtype)]
                * (capacity - len(group)))
            real, padded_cells = pad_waste(
                [g.payload.shape for g in group], padded, capacity)
        self.stats.real_cells += real
        self.stats.padded_cells += padded_cells

        if it0.domain == "graph" and it0.backend == "pure":
            out = fn(jnp.asarray(stack), jnp.asarray(it0.senders),
                     jnp.asarray(it0.receivers))
        else:
            out = fn(jnp.asarray(stack))
        labels, stats = out if has_stats else (out, None)
        labels = np.asarray(jax.block_until_ready(labels))

        for pos, g in enumerate(group):
            lab = (remap_flat_labels(labels[pos], padded, g.payload.shape)
                   if g.domain == "grid" else labels[pos])
            st = (None if stats is None else
                  {f: np.asarray(v)[pos].item()
                   for f, v in zip(stats._fields, stats)})
            outputs[(g.req_idx, g.role)] = (lab, st)

    # --- result assembly ------------------------------------------------------

    def _assemble(self, idx: int, req: TopologyRequest,
                  outputs: dict) -> TopologyResult:
        if req.query in ("cc", "manifold"):
            lab, st = outputs[(idx, ("labels",))]
            return TopologyResult(req.query, labels=jnp.asarray(lab),
                                  stats=st, tag=req.tag)
        if req.query == "ms":
            desc, st_d = outputs[(idx, ("desc",))]
            asc, st_a = outputs[(idx, ("asc",))]
            n = math.prod(desc.shape)
            dt = np.int64 if jax.config.jax_enable_x64 else np.int32
            seg = desc.astype(dt) * dt(n) + asc.astype(dt)
            stats = (None if st_d is None
                     else {"descending": st_d, "ascending": st_a})
            return TopologyResult("ms", ascending=jnp.asarray(asc),
                                  descending=jnp.asarray(desc),
                                  segmentation=jnp.asarray(seg),
                                  stats=stats, tag=req.tag)
        # threshold_sweep
        thr = np.asarray(req.thresholds).reshape(-1)
        labs, sts = [], []
        for k in range(thr.size):
            lab, st = outputs[(idx, ("sweep", k))]
            labs.append(lab)
            sts.append(st)
        stats = (None if sts[0] is None else
                 {f: [s[f] for s in sts] for f in sts[0]})
        return TopologyResult("threshold_sweep",
                              labels=jnp.asarray(np.stack(labs)),
                              stats=stats, tag=req.tag)
