"""Distributed Path Compression (paper Alg. 1 + Alg. 2) under shard_map.

Decomposition: N-D *blocks* over a multi-axis device mesh.  Mesh axis ``a``
decomposes grid axis ``a`` (a 1-D mesh recovers the original slab layout);
each block carries one layer of ghost vertices on every decomposed face —
the paper's "one layer of ghost vertices".  Ghost corners/edges are filled
by exchanging axis-by-axis on the progressively extended block, the standard
dimension-ordered halo exchange.

Grid extents need NOT divide the layout: blocks take the ceil-division
extent, the grid is padded up to ``layout * local`` per decomposed axis, and
padding is masked with sentinels that can never win an argmax or hook a
table row — order -1 (below every real order value) for manifolds, mask
False for CC, label -1 in the gathered boundary table (deviation (p) in
DESIGN.md).  `DPCStats.ghost_bytes`/`masked_ghost_fraction` count only
in-domain table slots; `pad_fraction` reports the padding overhead.

The local phase runs entirely in *local* extended-block ids.  Because every
vertex of the extended block has global coordinates ``origin + local``, the
local raveled order is exactly the global id order restricted to the block,
so id-maximum arguments (CC labels = largest member id) transfer verbatim;
local ids are converted to global flat ids by one gather through a
coordinate-arithmetic id map (replacing TTK's id-translation structures).

Phases (MS manifolds):
  1. halo exchange of the order field (one lax.ppermute pair per mesh axis);
  2. steepest init on the extended block; ghost vertices pretend to be
     maxima (point to themselves) — Alg. 1 lines 6-8;
  3. local path compression to the block fixpoint (no collectives);
  4. ONE global communication step: all_gather of every owned boundary
     *face* (two per decomposed axis) into a replicated flat table — the
     SPMD equivalent of Alg. 2's Gather->rank0->Scatter->Allgather staging
     (deviation (b) in DESIGN.md);
  5. pointer doubling on the gathered table — every device compresses the
     same table, resolving segments that stretch across multiple blocks
     (paper Fig. 2);
  6. final substitution: owned pointers that target any boundary vertex are
     replaced by the table's compressed target — Alg. 2 lines 27-33.

Connected components add the stitch pass locally (Alg. 3) and, on the
gathered table, a hook+propagate fixpoint over the static boundary
adjacency (all stencil edges between table vertices, which covers axis cuts
*and* diagonal block-to-block edges) and equal-label groups.  The paper
compresses the ghost table with path compression only; that is sufficient
for MS integral lines (strictly order-increasing chains) but not for CC
labels that must *merge* across a cut whose local roots are interior
vertices — deviation (d2) in DESIGN.md.  The fix stays within the paper's
single-communication-phase budget: it only post-processes the
already-gathered table.
"""
from __future__ import annotations

import math
from functools import cached_property, lru_cache, partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ._shardmap import shard_map_norep
from ._table import (TableView, chase_view, check_converged, check_table_mode,
                     make_group_max, hook_propagate, pointer_chase,
                     sharded_fixpoint, value_substitute)
from .stats import DPCStats
from .steepest import neighbor_offsets, shift_fill
from .pathcompress import path_compress

AXIS = "shards"                 # legacy 1-D axis name (make_flat_mesh interop)
BLOCK_AXES = ("bx", "by", "bz")  # axis names used by make_dpc_mesh layouts

_N_STATS = len(DPCStats._fields)


def make_dpc_mesh(layout, devices=None) -> Mesh:
    """Device mesh for a block decomposition.

    layout: int (1-D slabs, legacy "shards" axis) or a tuple of up to three
    per-axis block counts, e.g. (4, 2) or (2, 2, 2); mesh axis ``a``
    decomposes grid axis ``a``.
    """
    if isinstance(layout, (int, np.integer)):
        return jax.make_mesh((int(layout),), (AXIS,), devices=devices)
    layout = tuple(int(p) for p in layout)
    if not 1 <= len(layout) <= len(BLOCK_AXES):
        raise ValueError(f"layout {layout} must have 1..3 axes")
    return jax.make_mesh(layout, BLOCK_AXES[:len(layout)], devices=devices)


# --- static decomposition geometry ------------------------------------------


class BlockDecomp:
    """Static geometry of an N-D block decomposition of a structured grid.

    Grid axis ``a`` (a < k) is split into ``layout[a]`` ceil-division blocks
    mapped to mesh axis ``names[a]``; remaining grid axes stay whole.  When
    the extent does not divide, every block still gets the same static
    extent ``local[a] = ceil(grid[a] / layout[a])`` and the trailing cells
    (possibly whole trailing blocks) are padding, masked with sentinels that
    are inert in every phase — deviation (p) in DESIGN.md.  Provides the
    global<->local id arithmetic and the layout of the gathered boundary
    table: the table is the concatenation, over decomposed axes ``a``, of
    (nblocks, 2, face_size[a]) segments holding every block's lo/hi owned
    face along ``a`` (block order row-major in mesh-axis order, matching
    ``lax.all_gather(..., names)``).
    """

    def __init__(self, grid_shape, layout, names):
        self.grid = tuple(int(x) for x in grid_shape)
        self.layout = tuple(int(p) for p in layout)
        self.names = tuple(names)
        self.ndim = len(self.grid)
        self.k = len(self.layout)
        if self.k > self.ndim:
            raise ValueError(f"mesh has {self.k} axes but grid is "
                             f"{self.ndim}-D")
        self.local = tuple(
            -(-self.grid[i] // self.layout[i]) if i < self.k
            else self.grid[i]
            for i in range(self.ndim))
        # the statically padded grid the SPMD program actually runs on
        self.padded = tuple(
            self.local[i] * self.layout[i] if i < self.k else self.grid[i]
            for i in range(self.ndim))
        self.ragged = self.padded != self.grid
        self.ext = tuple(
            self.local[i] + 2 if i < self.k else self.local[i]
            for i in range(self.ndim))
        self.nblocks = math.prod(self.layout)
        self.size = math.prod(self.grid)
        if self.size < 2**31:
            self.id_dtype = jnp.int32
        elif jax.config.jax_enable_x64:
            self.id_dtype = jnp.int64
        else:
            # without x64, jnp silently downcasts int64 -> int32 and global
            # ids past 2**31 would wrap negative; refuse instead
            raise ValueError(
                f"grid has {self.size} >= 2**31 vertices; the int64 id path "
                "requires jax_enable_x64")
        # row-major strides of the global grid and of the block lattice
        self.stride = tuple(math.prod(self.grid[i + 1:])
                            for i in range(self.ndim))
        self.bstride = tuple(math.prod(self.layout[a + 1:])
                             for a in range(self.k))
        # per-axis owned-face geometry (face = local block minus that axis)
        self.face_stride, self.face_size, self.face_offset = [], [], []
        off = 0
        for a in range(self.k):
            st, acc = {}, 1
            for i in reversed([i for i in range(self.ndim) if i != a]):
                st[i] = acc
                acc *= self.local[i]
            self.face_stride.append(st)
            self.face_size.append(acc)
            self.face_offset.append(off)
            off += self.nblocks * 2 * acc
        self.table_size = off
        self.owned_slices = tuple(
            slice(1, self.local[i] + 1) if i < self.k else slice(None)
            for i in range(self.ndim))
        # closed-form count of in-domain table slots (pad slots excluded):
        # along axis a there are f_a valid lo/hi face positions, each
        # carrying prod(grid[i != a]) in-domain cells (the per-axis valid
        # cell counts sum back to the exact grid extent) — this is what
        # DPCStats.ghost_bytes reports (deviation (p) in DESIGN.md)
        self.n_valid_slots = 0
        for a in range(self.k):
            L = self.local[a]
            f = sum(int(b * L < self.grid[a]) + int(b * L + L - 1
                                                    < self.grid[a])
                    for b in range(self.layout[a]))
            self.n_valid_slots += f * (self.size // self.grid[a])
        self.pad_fraction = 1.0 - self.size / math.prod(self.padded)

    def ghost_mask(self) -> np.ndarray:
        """Boolean ext-block array marking the ghost layers."""
        m = np.zeros(self.ext, bool)
        for a in range(self.k):
            idx = [slice(None)] * self.ndim
            idx[a] = 0
            m[tuple(idx)] = True
            idx[a] = self.ext[a] - 1
            m[tuple(idx)] = True
        return m

    def boundary_pos(self, g, xp=jnp):
        """Map global flat ids to their canonical slot in the gathered
        boundary table.  Returns (is_boundary, flat_slot); a vertex on
        several faces (block edge/corner) is canonicalised to the lowest
        decomposed axis.  Works under numpy (static precompute) and jnp
        (traced lookups).  Only defined for in-domain ids — pad cells of a
        ragged decomposition never reach a lookup because their table
        entries carry the fixed sentinel -1 (deviation (p) in DESIGN.md)."""
        xs = [(g // self.stride[i]) % self.grid[i] for i in range(self.ndim)]
        B = 0
        for a in range(self.k):
            B = B + (xs[a] // self.local[a]) * self.bstride[a]
        is_b = xp.zeros_like(g, dtype=bool)
        pos = xp.zeros_like(g)
        for a in reversed(range(self.k)):
            L = self.local[a]
            xin = xs[a] % L
            on = (xin == 0) | (xin == L - 1)
            j = xp.where(xin == L - 1, 1, 0)
            r = 0
            for i in range(self.ndim):
                if i == a:
                    continue
                r = r + (xs[i] % self.local[i]) * self.face_stride[a][i]
            p = self.face_offset[a] + (B * 2 + j) * self.face_size[a] + r
            pos = xp.where(on, p, pos)
            is_b = is_b | on
        return is_b, pos

    # incremented on every boundary_coords build; the recompile-regression
    # test pins this to one build per decomposition (PR 9 satellite)
    _coords_builds = 0

    @cached_property
    def boundary_coords(self) -> np.ndarray:
        """(table_size, ndim) int32 global coordinates of every table slot,
        built ONCE per decomposition on the host and passed into the mapped
        programs as a replicated *argument* — an input buffer, not an
        in-graph iota cascade that XLA would constant-fold (rebake) into
        every executable that needs it."""
        BlockDecomp._coords_builds += 1
        return np.asarray(self.slot_coords(np), dtype=np.int32)

    @cached_property
    def boundary_coords_dev(self):
        """`boundary_coords` as a device array (uploaded once per decomp).
        The upload must stay concrete even when the first access happens
        inside someone else's trace (the serve engine jits the batch entry
        points) — caching a staged constant here would leak a tracer into
        every later caller."""
        with jax.ensure_compile_time_eval():
            return jnp.asarray(self.boundary_coords)

    def slot_coords(self, xp=jnp):
        """(table_size, ndim) global coordinates of every table slot.
        Prefer the cached `boundary_coords` host array: tracing this with
        xp=jnp bakes the O(table_size * ndim) constant into every
        executable."""
        parts = []
        for a in range(self.k):
            F = self.face_size[a]
            n = self.nblocks * 2 * F
            s = xp.arange(n, dtype=np.int32)
            B, j, r = s // (2 * F), (s % (2 * F)) // F, s % F
            cols = []
            for i in range(self.ndim):
                if i == a:
                    c = ((B // self.bstride[a]) % self.layout[a]
                         * self.local[a] + j * (self.local[a] - 1))
                else:
                    c = (r // self.face_stride[a][i]) % self.local[i]
                    if i < self.k:
                        c = ((B // self.bstride[i]) % self.layout[i]
                             * self.local[i] + c)
                cols.append(c)
            parts.append(xp.stack(cols, axis=1))
        return xp.concatenate(parts, axis=0)


@lru_cache(maxsize=128)
def _decomp_cached(grid, layout, names) -> BlockDecomp:
    return BlockDecomp(grid, layout, names)


def _decomp_for(mesh: Mesh, grid_shape) -> BlockDecomp:
    """Memoized per (grid, layout): repeated calls on the same geometry
    share one BlockDecomp, so `boundary_coords` (and the sharded-stack
    geometry) are built once, not per request."""
    names = tuple(mesh.axis_names)
    layout = tuple(mesh.shape[n] for n in names)
    return _decomp_cached(tuple(int(x) for x in grid_shape), layout, names)


_check_table_mode = check_table_mode  # shared with the graph backend


# --- shared traced helpers ---------------------------------------------------


def _pad_input(x, dec: BlockDecomp, fill):
    """Pad a global input up to the statically padded grid (deviation (p)):
    `fill` must be the phase's inert sentinel (order -1 / mask False), so
    padding can never win a steepest/mask argmax."""
    if not dec.ragged:
        return x
    pads = [(0, dec.padded[i] - dec.grid[i]) for i in range(dec.ndim)]
    return jnp.pad(x, pads, constant_values=fill)


def _unpad_output(x, dec: BlockDecomp):
    """Slice a padded global output back to the real grid extent."""
    if not dec.ragged:
        return x
    return x[tuple(slice(0, g) for g in dec.grid)]


def _owned_valid(dec: BlockDecomp):
    """Boolean owned-block array marking in-domain (non-pad) cells, from the
    block's position on the mesh (deviation (p) in DESIGN.md)."""
    total = None
    for a in range(dec.k):
        b = lax.axis_index(dec.names[a])
        x = b * dec.local[a] + jnp.arange(dec.local[a], dtype=jnp.int32)
        shape = [1] * dec.ndim
        shape[a] = -1
        v = (x < dec.grid[a]).reshape(shape)
        total = v if total is None else total & v
    return jnp.broadcast_to(total, dec.local)


def _halo_extend(ext, dim, name, n_blocks, fill):
    """Extend `ext` with one ghost slab per face along grid axis `dim`,
    exchanged over mesh axis `name` (fill at the domain boundary).  Applied
    axis-by-axis, so later axes forward earlier ghosts into the corners."""
    lo_src = lax.index_in_dim(ext, ext.shape[dim] - 1, dim, keepdims=True)
    hi_src = lax.index_in_dim(ext, 0, dim, keepdims=True)
    if n_blocks == 1:
        lo = jnp.full_like(lo_src, fill)
        hi = jnp.full_like(hi_src, fill)
    else:
        p = lax.axis_index(name)
        lo = lax.ppermute(lo_src, name,
                          [(i, i + 1) for i in range(n_blocks - 1)])
        hi = lax.ppermute(hi_src, name,
                          [(i + 1, i) for i in range(n_blocks - 1)])
        lo = jnp.where(p == 0, fill, lo)
        hi = jnp.where(p == n_blocks - 1, fill, hi)
    return jnp.concatenate([lo, ext, hi], axis=dim)


def _gid_map(dec: BlockDecomp):
    """Global flat id of every extended-block position (out-of-domain ghost
    coordinates produce ids that are never read: their order/mask fill keeps
    them off every pointer path)."""
    total = None
    for i in range(dec.ndim):
        if i < dec.k:
            b = lax.axis_index(dec.names[i])
            x = b * dec.local[i] - 1 + jnp.arange(dec.ext[i],
                                                  dtype=dec.id_dtype)
        else:
            x = jnp.arange(dec.grid[i], dtype=dec.id_dtype)
        shape = [1] * dec.ndim
        shape[i] = -1
        part = (x * dec.stride[i]).reshape(shape)
        total = part if total is None else total + part
    return total


def _gather_table(owned, dec: BlockDecomp):
    """The single communication phase: all_gather every block's owned lo/hi
    face along each decomposed axis into one replicated flat table laid out
    as BlockDecomp.boundary_pos expects."""
    parts = []
    for a in range(dec.k):
        lo = lax.index_in_dim(owned, 0, a, keepdims=False)
        hi = lax.index_in_dim(owned, dec.local[a] - 1, a, keepdims=False)
        bt = jnp.stack([lo.reshape(-1), hi.reshape(-1)])     # (2, F_a)
        g = lax.all_gather(bt, dec.names)                    # (nblocks, 2, F_a)
        parts.append(g.reshape(-1))
    return jnp.concatenate(parts)


def _own_faces(owned, dec: BlockDecomp):
    """This device's own row-chunk of the boundary table: the block's lo/hi
    face along each decomposed axis, flattened exactly like one block's
    segment of the gathered table (`row = local_face_offset[a] + j*F_a + r`).
    `_gather_table` == all_gather of every block's `_own_faces`."""
    parts = []
    for a in range(dec.k):
        lo = lax.index_in_dim(owned, 0, a, keepdims=False)
        hi = lax.index_in_dim(owned, dec.local[a] - 1, a, keepdims=False)
        parts.append(jnp.stack([lo.reshape(-1), hi.reshape(-1)]).reshape(-1))
    return jnp.concatenate(parts)


def _table_compress(T, dec: BlockDecomp, max_iter=64):
    """Pointer doubling on the gathered flat table (Alg. 2 lines 15-25).
    Entries < 0 (unmasked CC cells and the pad sentinels of deviation (p))
    and non-boundary targets are fixed.  The slot lookup is pure coordinate
    arithmetic (boundary_pos); the chase itself is the shared
    backend-agnostic loop in core/_table.py.  Returns (table, iters, ok)."""
    def lookup(t):
        is_b, pos = dec.boundary_pos(jnp.clip(t, 0), jnp)
        tv = t[jnp.clip(pos, 0, t.size - 1)]
        return jnp.where((t >= 0) & is_b, tv, t)

    view, iters, ok = chase_view(TableView(T, lookup, T.size), max_iter)
    return view.values, iters, ok


# --- sharded boundary table (table_mode="sharded", deviation (s)) ------------


class _ShardGeom:
    """Static geometry of the sharded boundary-table stack (deviation (s) in
    DESIGN.md §Table-sharding).

    Per device the stack is `n_chunks` copies of the per-block face-row
    layout (`rows` = both faces of every decomposed axis, `_own_faces`
    order): chunk 0 is the device's OWN faces, the rest a one-hop halo of
    lattice-neighbor blocks.  Axes with layout 1 contribute no halo; layout
    2 contributes ONE chunk (the swap partner is both the +1 and the -1
    neighbor); layout >= 3 contributes lo/hi chunks, with lattice-edge
    positions filled by inert sentinels (label -1 / mask False, the
    deviation-(p) contract).  When the stencil reaches no diagonal block
    pair (e.g. connectivity 6 on a 3-D lattice) the chunk set is the
    von-Neumann star (1 + sum(sz-1) chunks); otherwise the full Moore
    product (prod(sz)) is built by dimension-ordered forwarding, exactly
    like the ghost halo itself.
    """

    def __init__(self, dec: BlockDecomp, connectivity: int):
        self.dec = dec
        self.rows = dec.table_size // dec.nblocks
        self.local_off = [dec.face_offset[a] // dec.nblocks
                          for a in range(dec.k)]
        self.act = [a for a in range(dec.k) if dec.layout[a] > 1]
        self.sz = {a: (2 if dec.layout[a] == 2 else 3) for a in self.act}
        offs = neighbor_offsets(dec.ndim, connectivity)
        self.moore = any(
            sum(1 for a in self.act if off[a] != 0) >= 2 for off in offs)
        if self.moore:
            self.n_chunks = math.prod(self.sz[a] for a in self.act)
        else:
            self.n_chunks, self.vn_base = 1, {}
            for a in self.act:
                self.vn_base[a] = self.n_chunks
                self.n_chunks += self.sz[a] - 1
        self.stack_size = self.n_chunks * self.rows

    def exchange_fn(self, fill):
        """One halo-exchange round: own chunk -> flat (stack_size,) stack
        with the own chunk leading (`sharded_fixpoint` contract).  The Moore
        variant forwards the partial stack axis-by-axis so diagonal-neighbor
        chunks arrive via two axis hops (`_halo_extend`'s argument)."""
        dec = self.dec

        def axis_parts(src, a):
            L, name = dec.layout[a], dec.names[a]
            if L == 2:
                return [lax.ppermute(src, name, [(0, 1), (1, 0)])]
            lo = lax.ppermute(src, name, [(i, i + 1) for i in range(L - 1)])
            hi = lax.ppermute(src, name, [(i + 1, i) for i in range(L - 1)])
            p = lax.axis_index(name)
            return [jnp.where(p == 0, fill, lo),
                    jnp.where(p == L - 1, fill, hi)]

        if self.moore:
            def exchange(own):
                S, dims = own, 0
                for a in self.act:
                    S = jnp.stack([S] + axis_parts(S, a), axis=dims)
                    dims += 1
                return S.reshape(-1)
        else:
            def exchange(own):
                chunks = [own]
                for a in self.act:
                    chunks.extend(axis_parts(own, a))
                return jnp.concatenate(chunks) if len(chunks) > 1 else own
        return exchange

    def pos_to_stack(self, s):
        """Global table slot -> (in_stack, flat stack index).  Callers gate
        on `is_boundary` (and validity) before trusting either output."""
        dec = self.dec
        row = jnp.zeros_like(s)
        B = jnp.zeros_like(s)
        for a in range(dec.k):
            F2 = 2 * dec.face_size[a]
            off = dec.face_offset[a]
            within = (s >= off) & (s < off + dec.nblocks * F2)
            t = jnp.where(within, s - off, 0)
            row = jnp.where(within, self.local_off[a] + t % F2, row)
            B = jnp.where(within, t // F2, B)
        row = row.astype(jnp.int32)
        B = B.astype(jnp.int32)
        ok = jnp.ones_like(row, dtype=bool)
        chunk = jnp.zeros_like(row)
        nnz = jnp.zeros_like(row)
        pos = {}
        for a in self.act:
            c = (B // dec.bstride[a]) % dec.layout[a]
            d = c - lax.axis_index(dec.names[a])
            if dec.layout[a] == 2:
                pa = (d != 0).astype(jnp.int32)
            else:
                ok = ok & (jnp.abs(d) <= 1)
                pa = jnp.where(d == 0, 0, jnp.where(d == -1, 1, 2))
            pos[a] = pa
            nnz = nnz + (pa > 0)
        if self.moore:
            for a in self.act:
                chunk = chunk * self.sz[a] + pos[a]
        else:
            ok = ok & (nnz <= 1)
            for a in self.act:
                chunk = chunk + jnp.where(pos[a] > 0,
                                          self.vn_base[a] + pos[a] - 1, 0)
        return ok, chunk * self.rows + row

    def lookup_fn(self):
        """Value lookup through the stack (the sharded TableView lookup):
        in-stack boundary targets map through, everything else is fixed."""
        dec, size = self.dec, self.stack_size

        def lookup(t):
            is_b, s = dec.boundary_pos(jnp.clip(t, 0), jnp)
            ok, idx = self.pos_to_stack(s)
            tv = t[jnp.clip(idx, 0, size - 1)]
            return jnp.where((t >= 0) & is_b & ok, tv, t)
        return lookup

    def _chunk_block_coords(self, ci: int):
        """Traced per-axis block coordinates of (static) chunk `ci`."""
        dec = self.dec
        pos = {a: 0 for a in range(dec.k)}
        if self.moore:
            rest = ci
            for a in reversed(self.act):
                pos[a] = rest % self.sz[a]
                rest //= self.sz[a]
        else:
            for a in self.act:
                if self.vn_base[a] <= ci < self.vn_base[a] + self.sz[a] - 1:
                    pos[a] = ci - self.vn_base[a] + 1
        bc = []
        for a in range(dec.k):
            p = lax.axis_index(dec.names[a])
            if pos[a] == 0:
                bc.append(p)
            elif dec.layout[a] == 2:
                bc.append(1 - p)            # the swap partner
            else:
                bc.append(p - 1 if pos[a] == 1 else p + 1)
        return bc

    def stack_coords(self, coords):
        """(stack_size, ndim) global coordinates of every stack slot plus a
        per-slot validity mask (False on lattice-edge fill chunks).  Rows are
        gathered per chunk from the cached `boundary_coords` table — passed
        in as a traced argument, never baked."""
        dec = self.dec
        r_i = jnp.arange(self.rows, dtype=jnp.int32)
        parts, valids = [], []
        for ci in range(self.n_chunks):
            bc = self._chunk_block_coords(ci)
            valid, B = None, jnp.int32(0)
            for a in range(dec.k):
                v = (bc[a] >= 0) & (bc[a] < dec.layout[a])
                valid = v if valid is None else valid & v
                B = B + jnp.clip(bc[a], 0, dec.layout[a] - 1) * dec.bstride[a]
            gidx = jnp.zeros_like(r_i)
            for a in range(dec.k):
                lo = self.local_off[a]
                F2 = 2 * dec.face_size[a]
                within = (r_i >= lo) & (r_i < lo + F2)
                gidx = jnp.where(
                    within, dec.face_offset[a] + B * F2 + (r_i - lo), gidx)
            parts.append(coords[gidx])
            valids.append(jnp.broadcast_to(valid, (self.rows,)))
        return jnp.concatenate(parts), jnp.concatenate(valids)


def _shard_geom_for(dec: BlockDecomp, connectivity: int) -> _ShardGeom:
    cache = dec.__dict__.setdefault("_shard_geoms", {})
    key = int(connectivity)
    if key not in cache:
        cache[key] = _ShardGeom(dec, connectivity)
    return cache[key]


def _preduce_stats(dec: BlockDecomp, iters, rounds, ok):
    """Mesh-wide reductions of per-device sharded fixpoint stats."""
    return (lax.pmax(iters, dec.names), rounds,
            lax.pmin(ok.astype(jnp.int32), dec.names))


# --- MS manifolds ------------------------------------------------------------


def _sharded_manifold_resolve(owned, dec: BlockDecomp, connectivity,
                              max_iter: int):
    """Sharded replacement of steps 4-6 (gather + compress + substitute)
    for manifolds: a neighbor-relay fixpoint on the own+halo stack.  Each
    outer round rebuilds the view from fresh estimates and re-chases every
    own slot from its ORIGINAL one-hop pointer through the view (in-view
    segments compress by pointer doubling within the round; the estimate a
    chain adopts at its deepest in-view slot is that neighbor's previous
    round's reach).  Converges to the chains' unique terminals — the exact
    values the replicated chase produces (DESIGN.md §Table-sharding)."""
    geom = _shard_geom_for(dec, connectivity)
    T0 = _own_faces(owned, dec)
    lookup = geom.lookup_fn()
    exchange = geom.exchange_fn(-1)

    def refine(stack):
        view = TableView(stack.at[:geom.rows].set(T0), lookup, geom.rows)
        view, iters, ok = chase_view(view, max_iter)
        return view.values, iters, ok

    def reduce_any(x):
        return lax.pmax(x.astype(jnp.int32), dec.names) > 0

    stackT, _, rounds, iters, ok = sharded_fixpoint(
        T0, exchange, refine, reduce_any, max_rounds=max_iter)

    o = owned.ravel()
    is_b, s = dec.boundary_pos(jnp.clip(o, 0), jnp)
    okp, idx = geom.pos_to_stack(s)
    final = jnp.where((o >= 0) & is_b & okp,
                      stackT[jnp.clip(idx, 0, geom.stack_size - 1)], o)
    return final, geom, rounds, iters, ok


def _manifold_block(order_blk, *, dec: BlockDecomp, connectivity,
                    fused_impl: str = "auto", table_mode: str = "replicated",
                    table_max_iter: int = 64):
    """Always runs the *descending* direction; the ascending manifold is
    obtained by flipping the order field outside (keeps the -1 halo fill
    strictly below every candidate)."""
    # lazy: repro.kernels imports repro.core.steepest at module load
    from repro.kernels.ops import fused_local_phase

    # 1. order halo (fill -1: below every real order value, never steepest)
    ext = order_blk
    for a in range(dec.k):
        ext = _halo_extend(ext, a, dec.names[a], dec.layout[a], -1)

    # 2.+3a. fused steepest init + in-tile saturation in local ids, ghosts
    #    pretending to be maxima (Alg. 1 lines 6-8); on the jnp fallback this
    #    is exactly the unfused init (kernel_rounds == 0)
    d, kernel_rounds = fused_local_phase(
        ext, connectivity, mode="manifold",
        self_mask=jnp.asarray(dec.ghost_mask()), impl=fused_impl)
    d = d.ravel()

    # 3. local compression to the block fixpoint (Alg. 1 lines 9-19; with
    #    the kernel path it starts near-converged — only chains crossing
    #    tile boundaries remain)
    d, local_iters = path_compress(d)

    # 4. to global ids + the single communication phase (Alg. 2); pad cells
    #    of a ragged block carry the sentinel -1, which the chase fixes and
    #    the substitution skips (deviation (p) in DESIGN.md)
    owned = _gid_map(dec).ravel()[d].reshape(dec.ext)[dec.owned_slices]
    if dec.ragged:
        owned = jnp.where(_owned_valid(dec), owned, dec.id_dtype(-1))
    isz = np.dtype(dec.id_dtype).itemsize

    if table_mode == "replicated":
        # 4. the single communication phase (Alg. 2) + 5. ghost-table
        #    compression (identical on every device)
        T = _gather_table(owned, dec)
        T, table_iters, chase_ok = _table_compress(T, dec, table_max_iter)

        # 6. final substitution (Alg. 2 lines 27-33)
        o = owned.ravel()
        is_b, pos = dec.boundary_pos(jnp.clip(o, 0), jnp)
        final = jnp.where((o >= 0) & is_b,
                          T[jnp.clip(pos, 0, T.size - 1)], o)
        comm = jnp.int32(1)
        exch_rounds = jnp.int32(0)
        ghost_bytes = jnp.float32(dec.n_valid_slots * isz)
        table_bytes = jnp.float32(dec.table_size * isz)
        converged = chase_ok.astype(jnp.int32)
    else:
        # 4-6. sharded: own faces + one-hop halo, neighbor-relay fixpoint
        final, geom, exch_rounds, iters, ok = _sharded_manifold_resolve(
            owned, dec, connectivity, table_max_iter)
        table_iters, _, converged = _preduce_stats(dec, iters, exch_rounds,
                                                   ok)
        comm = exch_rounds                 # one exchange phase per round
        halo = geom.stack_size - geom.rows
        ghost_bytes = jnp.float32(halo * isz) * exch_rounds.astype(
            jnp.float32)
        table_bytes = jnp.float32((geom.stack_size + geom.rows) * isz)

    li = lax.pmax(local_iters, dec.names)
    kr = lax.pmax(kernel_rounds, dec.names)
    stats = DPCStats(
        local_iters=li,
        table_iters=table_iters,
        stitch_rounds=jnp.int32(0),
        ghost_bytes=ghost_bytes,
        masked_ghost_fraction=jnp.float32(1.0),
        pad_fraction=jnp.float32(dec.pad_fraction),
        comm_phases=comm,
        kernel_rounds=kr,
        # the unfused local loop needs >= kr rounds to resolve the same
        # in-tile chains, the fused one used li — a provable lower bound
        global_iters_saved=jnp.maximum(kr - li, 0),
        table_bytes_peak=table_bytes,
        exchange_rounds=exch_rounds,
        converged=converged,
    )
    return final.reshape(order_blk.shape), stats


def distributed_manifold(order, mesh: Mesh, connectivity: int = 6,
                         descending: bool = True, fused_impl: str = "auto",
                         table_mode: str = "replicated",
                         table_max_iter: int = 64):
    """Descending (or ascending) manifold of a block-sharded order field.

    order: int array of ANY extent (mesh axis a decomposes grid axis a;
    non-divisible extents are padded with inert sentinels, deviation (p) in
    DESIGN.md).  Returns the label grid (same extent as `order`) and
    replicated DPCStats.  fused_impl selects the block-local phase
    implementation (repro.kernels.ops.fused_local_phase); table_mode picks
    the boundary-table layout — "replicated" (one all_gather) or "sharded"
    (own faces + one-hop halo, outer exchange rounds; deviation (s)); labels
    are bit-identical across all choices.
    """
    _check_table_mode(table_mode)
    dec = _decomp_for(mesh, order.shape)
    if not descending:
        order = order.size - 1 - order  # ascending = descending on flipped order
    order = _pad_input(order, dec, -1)  # -1: below every real order value
    fn = partial(_manifold_block, dec=dec, connectivity=connectivity,
                 fused_impl=fused_impl, table_mode=table_mode,
                 table_max_iter=table_max_iter)
    spec = P(*dec.names, *([None] * (order.ndim - dec.k)))
    mapped = shard_map_norep(fn, mesh, (spec,),
                             (spec, DPCStats(*([P()] * _N_STATS))))
    labels, stats = mapped(order)
    check_converged(stats.converged, "distributed_manifold", table_max_iter)
    return _unpad_output(labels, dec), stats


# --- connected components ----------------------------------------------------


def _ext_stitch(d, mask_ext, connectivity, sentinel):
    """Stitch on the extended block in local ids (Alg. 3 ll. 25-29):
    scatter-max at position d[v]."""
    out = d
    dg = d.reshape(mask_ext.shape)
    m = mask_ext.ravel()
    for off in neighbor_offsets(mask_ext.ndim, connectivity):
        u_label = shift_fill(dg, off, -1).ravel()
        valid = m & shift_fill(mask_ext, off, False).ravel() & (u_label >= 0)
        tgt = jnp.where(valid, out, sentinel)
        out = out.at[tgt].max(jnp.where(valid, u_label, -1), mode="drop")
    return out


def _cc_local_fixpoint(d, mask_ext, connectivity, max_rounds=64):
    d, it0 = path_compress(d)
    sentinel = d.size

    def cond(s):
        _, ch, r, _ = s
        return ch & (r < max_rounds)

    def body(s):
        cur, _, r, its = s
        st = _ext_stitch(cur, mask_ext, connectivity, sentinel)
        nxt, it = path_compress(st)
        return nxt, jnp.any(nxt != cur), r + jnp.int32(1), its + it

    d, _, rounds, its = lax.while_loop(
        cond, body, (d, jnp.asarray(True), jnp.int32(0), it0))
    # it0 separately: the fused kernel pre-saturates exactly this first
    # compression, so the round-saving bound compares kernel_rounds to it0
    return d, rounds, its, it0


def _table_propagate(Tstar, Mflat, coords, dec: BlockDecomp, connectivity,
                     max_iter=64):
    """Hook + propagate on the gathered flat table: fixpoint of
      (a) max across masked stencil edges between boundary vertices (slot
          adjacency derived arithmetically per round — covers axis cuts and
          diagonal block pairs without a precomputed table),
      (b) max within equal-original-label groups (sorted-runs segment_max).
    Computes, for every boundary slot, the largest label of its global
    component.  Deviation (d2): the paper's path compression alone cannot
    perform these merges.  The group machinery and the fixpoint loop are
    shared with the unstructured backend (core/_table.py); only `cut_max`
    — slot adjacency by coordinate arithmetic — is block-specific.
    `coords` is the cached (table_size, ndim) slot-coordinate table, passed
    in as a traced argument (see BlockDecomp.boundary_coords)."""
    msize = Tstar.size
    group_max, perm, sorted_vals = make_group_max(Tstar)

    grid = jnp.asarray(dec.grid, dtype=jnp.int32)
    stride = jnp.asarray(dec.stride, dtype=dec.id_dtype)
    offsets = neighbor_offsets(dec.ndim, connectivity)

    def cut_max(L):
        best = L
        for off in offsets:
            nx = coords + jnp.asarray(off, dtype=jnp.int32)
            valid = jnp.all((nx >= 0) & (nx < grid), axis=1)
            g = (jnp.clip(nx, 0, grid - 1).astype(dec.id_dtype)
                 * stride).sum(axis=1)
            is_b, pos = dec.boundary_pos(g, jnp)
            ok = valid & is_b
            safe = jnp.clip(pos, 0, msize - 1)
            nl = jnp.where(ok, L[safe], -1)
            nm = jnp.where(ok, Mflat[safe], False)
            best = jnp.where(Mflat & nm, jnp.maximum(best, nl), best)
        return best

    L, iters, ok = hook_propagate(Tstar, cut_max, group_max, max_iter)
    return L, (perm, sorted_vals), iters, ok


def _sharded_cc_resolve(owned, mask_owned, coords, dec: BlockDecomp,
                        connectivity, gather_mask: bool, max_iter: int):
    """Sharded replacement of CC steps 4-6: a max-flooding fixpoint on the
    own+halo stack.  No chase stage is needed — the flood relation (masked
    stencil cut edges between in-stack slots + equal-ORIGINAL-label groups
    within the stack) connects exactly the slots of each global component,
    and its unique monotone fixpoint is the component's max vertex id, the
    same value the replicated chase+hook+propagate computes (DESIGN.md
    §Table-sharding).  The static label/mask stacks are exchanged once
    (building the per-device group structure); each outer round then
    exchanges only the evolving estimates."""
    geom = _shard_geom_for(dec, connectivity)
    T0 = _own_faces(owned, dec)
    exchange = geom.exchange_fn(-1)
    T0s = exchange(T0)                       # static: group structure
    if gather_mask:
        Ms = geom.exchange_fn(False)(_own_faces(mask_owned, dec))
    else:
        Ms = T0s >= 0                        # labels are -1 iff unmasked
    group_max, perm, sorted_vals = make_group_max(T0s)

    scoords, svalid = geom.stack_coords(coords)
    grid = jnp.asarray(dec.grid, dtype=jnp.int32)
    stride = jnp.asarray(dec.stride, dtype=dec.id_dtype)
    offsets = neighbor_offsets(dec.ndim, connectivity)

    def cut_max(L):
        best = L
        for off in offsets:
            nx = scoords + jnp.asarray(off, dtype=jnp.int32)
            valid = jnp.all((nx >= 0) & (nx < grid), axis=1) & svalid
            g = (jnp.clip(nx, 0, grid - 1).astype(dec.id_dtype)
                 * stride).sum(axis=1)
            is_b, s = dec.boundary_pos(g, jnp)
            okn, idx = geom.pos_to_stack(s)
            ok = valid & is_b & okn
            safe = jnp.clip(idx, 0, geom.stack_size - 1)
            nl = jnp.where(ok, L[safe], -1)
            nm = jnp.where(ok, Ms[safe], False)
            best = jnp.where(Ms & nm, jnp.maximum(best, nl), best)
        return best

    def refine(stack):
        return hook_propagate(stack, cut_max, group_max, max_iter)

    def reduce_any(x):
        return lax.pmax(x.astype(jnp.int32), dec.names) > 0

    stackG, _, rounds, iters, ok = sharded_fixpoint(
        T0, exchange, refine, reduce_any, max_rounds=max_iter)

    # substitution: adopt the flooded value at the own label's slot when it
    # has one, then the value search over the STATIC stack labels (an owned
    # interior root is not a slot but shares its value with its piece's cut
    # vertices, which are in the own chunk whenever the piece reaches a cut)
    o = owned.ravel()
    is_b, s = dec.boundary_pos(jnp.clip(o, 0), jnp)
    okp, idx = geom.pos_to_stack(s)
    chased = jnp.where((o >= 0) & is_b & okp,
                       stackG[jnp.clip(idx, 0, geom.stack_size - 1)], o)
    final = value_substitute(o, chased, sorted_vals, stackG[perm])
    return final, Ms, geom, rounds, iters, ok


def _cc_block(mask_blk, coords=None, *, dec: BlockDecomp, connectivity,
              gather_mask: bool = True, fused_impl: str = "auto",
              table_mode: str = "replicated", table_max_iter: int = 64):
    """gather_mask=False is the §Perf variant: the boundary mask is exactly
    (T >= 0) — labels are -1 where unmasked — so the mask all-gather is
    redundant and dropped (less exchange traffic, bit-identical).

    `coords` is the decomposition's boundary slot-coordinate table; the
    public entry points thread `dec.boundary_coords_dev` through the
    shard_map as an argument so the O(table_size * ndim) constant is not
    rebaked into every executable.  Direct internal callers may omit it —
    the fallback closes over the cached constant (old behaviour)."""
    if coords is None:
        coords = dec.boundary_coords_dev
    # lazy: repro.kernels imports repro.core.steepest at module load
    from repro.kernels.ops import fused_local_phase

    # 1. mask halo (fill False: domain boundary is never masked)
    ext = mask_blk
    for a in range(dec.k):
        ext = _halo_extend(ext, a, dec.names[a], dec.layout[a], False)

    # 2.(+first compress) fused init: largest masked neighbor id, masked
    #    ghosts pretending self, saturated in-tile by the kernel path
    d, kernel_rounds = fused_local_phase(
        ext, connectivity, mode="cc",
        self_mask=jnp.asarray(dec.ghost_mask()), impl=fused_impl)
    d = d.ravel()

    # 3. local CC fixpoint (stitch + compress, Alg. 3)
    d, stitch_rounds, local_iters, it0 = _cc_local_fixpoint(
        d, ext, connectivity)

    # 4. to global ids
    gid = _gid_map(dec).ravel()
    dg = jnp.where(d >= 0, gid[jnp.clip(d, 0)], -1).reshape(dec.ext)
    owned = dg[dec.owned_slices]
    isz = np.dtype(dec.id_dtype).itemsize

    if table_mode == "replicated":
        # 4b. the single communication phase: labels (+ masks)
        T = _gather_table(owned, dec)
        if gather_mask:
            M = _gather_table(ext[dec.owned_slices], dec)
        else:
            M = T >= 0             # labels are -1 exactly where unmasked

        # 5a. positional chase (the paper's table compression — resolves
        #     chains through ghost labels, e.g. a part labeled with a
        #     ghost's id)
        Tstar, table_iters, chase_ok = _table_compress(T, dec,
                                                       table_max_iter)
        # 5b. hook + propagate (deviation (d2)): merge labels across cuts
        G, (perm, sorted_vals), prop_iters, prop_ok = _table_propagate(
            Tstar, M, coords, dec, connectivity, table_max_iter)

        # 6. substitution: chase own label through the table, then take its
        #    group's propagated maximum (value search over the sorted table)
        o = owned.ravel()
        is_b, pos = dec.boundary_pos(jnp.clip(o, 0), jnp)
        chased = jnp.where((o >= 0) & is_b,
                           Tstar[jnp.clip(pos, 0, Tstar.size - 1)], o)
        final = value_substitute(o, chased, sorted_vals, G[perm])

        table_iters = table_iters + prop_iters
        comm = jnp.int32(1)
        exch_rounds = jnp.int32(0)
        converged = (chase_ok & prop_ok).astype(jnp.int32)
        ghost_bytes = (jnp.float32(dec.n_valid_slots * isz)
                       + (jnp.float32(dec.n_valid_slots) if gather_mask
                          else 0.0))
        table_bytes = jnp.float32(dec.table_size * (isz + 1))
        masked_frac = (jnp.sum(M).astype(jnp.float32)
                       / jnp.float32(max(dec.n_valid_slots, 1)))
    else:
        # 4b-6. sharded: max-flooding on the own+halo stack (no gather)
        final, Ms, geom, exch_rounds, iters, ok = _sharded_cc_resolve(
            owned, ext[dec.owned_slices], coords, dec, connectivity,
            gather_mask, table_max_iter)
        table_iters, _, converged = _preduce_stats(dec, iters, exch_rounds,
                                                   ok)
        comm = exch_rounds + jnp.int32(1)  # +1: the static label/mask stack
        halo = geom.stack_size - geom.rows
        ghost_bytes = (jnp.float32(halo * isz)
                       * (exch_rounds.astype(jnp.float32) + 1.0)
                       + (jnp.float32(halo) if gather_mask else 0.0))
        # evolving stack + static label stack + own chunk + bool mask stack
        table_bytes = jnp.float32((2 * geom.stack_size + geom.rows) * isz
                                  + geom.stack_size)
        # global fraction over in-domain slots (== the replicated number:
        # pad slots are mask-False on both paths, deviation (p))
        masked_frac = (lax.psum(
            jnp.sum(Ms[:geom.rows]).astype(jnp.float32), dec.names)
            / jnp.float32(max(dec.n_valid_slots, 1)))

    # pad table slots are label -1 / mask False by construction (the input
    # mask is padded False, deviation (p)), so they are excluded here
    kr = lax.pmax(kernel_rounds, dec.names)
    i0 = lax.pmax(it0, dec.names)
    stats = DPCStats(
        local_iters=lax.pmax(local_iters, dec.names),
        table_iters=table_iters,
        stitch_rounds=lax.pmax(stitch_rounds, dec.names),
        ghost_bytes=ghost_bytes,
        masked_ghost_fraction=masked_frac,
        pad_fraction=jnp.float32(dec.pad_fraction),
        comm_phases=comm,
        kernel_rounds=kr,
        # the kernel pre-saturates the FIRST compression only; the unfused
        # first compression needs >= kr rounds, the fused one used i0
        global_iters_saved=jnp.maximum(kr - i0, 0),
        table_bytes_peak=table_bytes,
        exchange_rounds=exch_rounds,
        converged=converged,
    )
    return final.reshape(mask_blk.shape), stats


def distributed_connected_components(mask, mesh: Mesh, connectivity: int = 6,
                                     gather_mask: bool = True,
                                     fused_impl: str = "auto",
                                     table_mode: str = "replicated",
                                     table_max_iter: int = 64):
    """Mask-implicit connected components of a block-sharded grid (Alg. 3 +
    Alg. 2).  Any grid extent works: non-divisible extents are padded with
    mask=False sentinels, which are inert in every phase (deviation (p) in
    DESIGN.md).  Returns (labels, DPCStats); labels carry the largest vertex
    id of the component, -1 where unmasked.  gather_mask=False drops the
    redundant mask exchange (§Perf); fused_impl selects the block-local
    phase implementation; table_mode="sharded" keeps the boundary table
    distributed (deviation (s)).  Labels are bit-identical across all
    choices."""
    _check_table_mode(table_mode)
    dec = _decomp_for(mesh, mask.shape)
    mask = _pad_input(mask, dec, False)  # padding is never masked
    fn = partial(_cc_block, dec=dec, connectivity=connectivity,
                 gather_mask=gather_mask, fused_impl=fused_impl,
                 table_mode=table_mode, table_max_iter=table_max_iter)
    spec = P(*dec.names, *([None] * (mask.ndim - dec.k)))
    mapped = shard_map_norep(fn, mesh, (spec, P(None, None)),
                             (spec, DPCStats(*([P()] * _N_STATS))))
    labels, stats = mapped(mask, dec.boundary_coords_dev)
    check_converged(stats.converged, "distributed_connected_components",
                    table_max_iter)
    return _unpad_output(labels, dec), stats


# --- batched (multi-tenant) entry points --------------------------------------
# One shard_map over a request-leading batch dim: the per-block program is
# vmapped, so the halo ppermutes and the ONE boundary all_gather each fire
# once for the whole batch — compilation AND the communication phase are
# amortised across tenants (the serving-engine contract, DESIGN.md §Serve).
# Labels are bit-identical per item to the single-request entry points; the
# returned DPCStats carry a leading (B,) request dim.


def _pad_input_batch(x, dec: BlockDecomp, fill):
    """`_pad_input` for a (B, *grid) stack (grid axes shifted right by 1)."""
    if not dec.ragged:
        return x
    pads = [(0, 0)] + [(0, dec.padded[i] - dec.grid[i])
                       for i in range(dec.ndim)]
    return jnp.pad(x, pads, constant_values=fill)


def _batched_block_call(fn, mesh, dec: BlockDecomp, x, extra=()):
    """`extra` holds replicated non-batched args (e.g. the slot-coordinate
    table), broadcast across both the request dim and the mesh."""
    spec = P(None, *dec.names, *([None] * (x.ndim - 1 - dec.k)))
    especs = tuple(P(*([None] * np.ndim(e))) for e in extra)
    vfn = jax.vmap(fn, in_axes=(0,) + (None,) * len(extra))
    mapped = shard_map_norep(vfn, mesh, (spec,) + especs,
                             (spec, DPCStats(*([P(None)] * _N_STATS))))
    labels, stats = mapped(x, *extra)
    if dec.ragged:
        labels = labels[(slice(None),) + tuple(slice(0, g) for g in dec.grid)]
    return labels, stats


def distributed_manifold_batch(orders, mesh: Mesh, connectivity: int = 6,
                               descending: bool = True,
                               fused_impl: str = "auto",
                               table_mode: str = "replicated",
                               table_max_iter: int = 64):
    """Batched `distributed_manifold`: orders is a (B, *grid) stack of order
    fields sharing one extent; returns ((B, *grid) labels, DPCStats with a
    leading (B,) dim).  Per item bit-identical to the single-request call."""
    _check_table_mode(table_mode)
    dec = _decomp_for(mesh, orders.shape[1:])
    if not descending:
        orders = dec.size - 1 - orders  # ascending = descending on flipped
    orders = _pad_input_batch(orders, dec, -1)
    fn = partial(_manifold_block, dec=dec, connectivity=connectivity,
                 fused_impl=fused_impl, table_mode=table_mode,
                 table_max_iter=table_max_iter)
    labels, stats = _batched_block_call(fn, mesh, dec, orders)
    check_converged(stats.converged, "distributed_manifold_batch",
                    table_max_iter)
    return labels, stats


def distributed_connected_components_batch(masks, mesh: Mesh,
                                           connectivity: int = 6,
                                           gather_mask: bool = True,
                                           fused_impl: str = "auto",
                                           table_mode: str = "replicated",
                                           table_max_iter: int = 64):
    """Batched `distributed_connected_components`: masks is a (B, *grid)
    stack of feature masks sharing one extent; returns ((B, *grid) labels,
    DPCStats with a leading (B,) dim).  Per item bit-identical to the
    single-request call."""
    _check_table_mode(table_mode)
    dec = _decomp_for(mesh, masks.shape[1:])
    masks = _pad_input_batch(masks, dec, False)
    fn = partial(_cc_block, dec=dec, connectivity=connectivity,
                 gather_mask=gather_mask, fused_impl=fused_impl,
                 table_mode=table_mode, table_max_iter=table_max_iter)
    labels, stats = _batched_block_call(fn, mesh, dec, masks,
                                        extra=(dec.boundary_coords_dev,))
    check_converged(stats.converged, "distributed_connected_components_batch",
                    table_max_iter)
    return labels, stats
