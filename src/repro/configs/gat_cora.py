"""gat-cora [gnn]: n_layers=2 d_hidden=8 n_heads=8 aggregator=attn
[arXiv:1710.10903; paper]"""
from repro.models.gnn import GATConfig
from .gnn_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "gnn"


def full_config() -> GATConfig:
    return GATConfig(name="gat-cora", n_layers=2, d_hidden=8, n_heads=8,
                     d_in=1433, n_classes=7)


def smoke_config() -> GATConfig:
    return GATConfig(name="gat-cora-smoke", n_layers=2, d_hidden=4,
                     n_heads=2, d_in=16, n_classes=7)
