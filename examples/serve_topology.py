"""Serving topology queries: the batched multi-tenant engine in 60 seconds.

Builds a mixed workload (CC masks, Morse-Smale segmentations, manifold
queries, threshold sweeps, over several ragged grid extents), serves it
through `repro.serve.TopologyEngine`, and checks the two contracts from
DESIGN.md §Serve:

  1. every batched result is bit-identical to the sequential
     `repro.topology.submit` path, and
  2. replaying the same layouts compiles nothing new — the second bucket
     occupant is served from the executable cache (hit rate > 0).

  PYTHONPATH=src python examples/serve_topology.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import configs
from repro.topology import submit_many
from repro.serve import TopologyEngine
from repro.serve.workload import synthetic_requests

cfg = configs.get("serve_topology").smoke_config()
reqs = synthetic_requests(10, cfg.shapes, mix=cfg.mix,
                          connectivity=cfg.connectivity,
                          sweep_k=cfg.sweep_k, seed=0)
print(f"workload: {len(reqs)} requests over extents "
      f"{sorted({r.shape() for r in reqs})}")

eng = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch)
t0 = time.perf_counter()
batched = eng.submit_batch(reqs)
t_batched = time.perf_counter() - t0
s = eng.stats
print(f"cold pass: {len(reqs)} requests -> {s.items} items -> "
      f"{s.batches} executions in {t_batched * 1e3:.0f}ms "
      f"(pad_fraction={s.pad_fraction:.2f})")

# contract 1: bit-identical to the sequential facade
t0 = time.perf_counter()
sequential = submit_many(reqs)
t_seq = time.perf_counter() - t0
for b, q in zip(batched, sequential):
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, w = getattr(b, f), getattr(q, f)
        assert (a is None) == (w is None)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(w))
print(f"parity: engine == sequential facade, bit-for-bit "
      f"(sequential pass took {t_seq * 1e3:.0f}ms)")

# contract 2: replaying the layouts hits the executable cache
misses = s.cache_misses
t0 = time.perf_counter()
eng.submit_batch(reqs)
t_warm = time.perf_counter() - t0
assert s.cache_misses == misses, "replay must not compile anything new"
assert s.cache_hits > 0 and s.hit_rate > 0
print(f"warm pass: {t_warm * 1e3:.0f}ms "
      f"({len(reqs) / max(t_warm, 1e-9):.0f} req/s); "
      f"cache {s.cache_hits} hits / {s.cache_misses} misses "
      f"(hit_rate={s.hit_rate:.2f})")
print("engine stats:", eng.stats.as_dict())
print("OK")
