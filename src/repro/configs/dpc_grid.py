"""dpc-grid — the paper's own workload: Morse-Smale segmentation and
connected components of Perlin-noise structured grids (paper §5).

Shapes mirror the paper's strong-scaling study; 1024^3 is the largest grid
whose flat ids fit int32 (2048^3+ takes the int64 path, as the paper's
32/64-bit id discussion prescribes).

`layout` is the block decomposition (per-grid-axis block counts) used for
the distributed runs; mesh axis a decomposes grid axis a.  A 1-D layout
recovers the original slab decomposition; the full config uses a 3-D block
lattice, the paper's setup for its best surface-to-volume ratio."""
import dataclasses

FAMILY = "dpc"


@dataclasses.dataclass(frozen=True)
class DPCConfig:
    name: str = "dpc-grid"
    connectivity: int = 6
    threshold_quantile: float = 0.9   # paper's "top 10%" feature mask
    arch: str = "dpc"
    # §Perf: the CC boundary mask equals (labels >= 0); gather_mask=False
    # drops the redundant mask all_gather from the ONE exchange
    gather_mask: bool = True
    # block decomposition; cells fall back to the flat 1-D mesh when the
    # layout does not match the available device count
    layout: tuple = (8, 8, 4)         # 256 chips, one pod


SHAPES = {
    "grid_512": {"kind": "dpc", "dims": (512, 512, 512)},
    "grid_1024": {"kind": "dpc", "dims": (1024, 1024, 1024)},
    "cc_1024": {"kind": "dpc_cc", "dims": (1024, 1024, 1024)},
    "cc_512": {"kind": "dpc_cc", "dims": (512, 512, 512)},
    # prime extents: the paper's real datasets are not multiples of the
    # node count — exercised via pad-and-mask (deviation (p) in DESIGN.md)
    "grid_ragged": {"kind": "dpc", "dims": (971, 613, 431)},
    "cc_ragged": {"kind": "dpc_cc", "dims": (971, 613, 431)},
}

# smoke grids: small enough to lower fast; ragged shapes keep their prime
# extents (nothing needs to divide the mesh since pad-and-mask landed)
SMOKE_SHAPES = {
    "grid_512": {"kind": "dpc", "dims": (512, 8, 8)},
    "grid_1024": {"kind": "dpc", "dims": (1024, 8, 8)},
    "cc_1024": {"kind": "dpc_cc", "dims": (1024, 8, 8)},
    "cc_512": {"kind": "dpc_cc", "dims": (512, 8, 8)},
    "grid_ragged": {"kind": "dpc", "dims": (97, 61, 43)},
    "cc_ragged": {"kind": "dpc_cc", "dims": (97, 61, 43)},
}

# shard layouts exercised by the scaling benchmarks (1-D slabs vs 2-D/3-D
# blocks at equal device counts)
SCALING_LAYOUTS = ((1,), (2,), (4,), (8,), (2, 2), (2, 4), (2, 2, 2))


def full_config() -> DPCConfig:
    return DPCConfig()


def smoke_config() -> DPCConfig:
    return DPCConfig(name="dpc-grid-smoke", layout=(2, 2, 2))
