"""GNN substrate: GAT, SchNet, MeshGraphNet, DimeNet.

All message passing is edge-list `segment_sum`/`segment_max` over a padded
`GraphBatch` (JAX sparse is BCOO-only; scatter-by-edge-index IS the system,
per the assignment).  This is the same gather/scatter regime as the
unstructured DPC path (core/steepest.graph_*), and DPC-CC runs directly on
these batches (see data/graphs.py pipeline integration).

Batch layout (fixed shapes; -pads masked):
  node_feat (N, F) | positions (N, 3) | senders/receivers (E,)
  node_mask (N,) | edge_mask (E,) | graph_ids (N,) | labels
  triplet_src/dst (T,)  — DimeNet only: edge-pair (k->j, j->i) lists
Padded edges point at node N-1 with edge_mask=0 and contribute zeros.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.core import dense_init
from repro.runtime.meshctx import constrain


# --- common ------------------------------------------------------------------


def segment_softmax(logits, segments, num_segments, mask=None):
    """Numerically-stable softmax of edge logits grouped by receiver."""
    if mask is not None:
        logits = jnp.where(mask, logits, -1e30)
    mx = jax.ops.segment_max(logits, segments, num_segments=num_segments)
    mx = jnp.nan_to_num(mx, neginf=0.0)
    e = jnp.exp(logits - mx[segments])
    if mask is not None:
        e = jnp.where(mask, e, 0.0)
    den = jax.ops.segment_sum(e, segments, num_segments=num_segments)
    return e / jnp.maximum(den[segments], 1e-20)


def _mlp_init(key, dims, dtype=jnp.float32):
    keys = jax.random.split(key, len(dims) - 1)
    return [{"w": dense_init(k, i, o, dtype), "b": jnp.zeros((o,), dtype)}
            for k, i, o in zip(keys, dims[:-1], dims[1:])]


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, l in enumerate(params):
        x = x @ l["w"] + l["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


def shifted_softplus(x):
    return jax.nn.softplus(x) - np.log(2.0)


# --- GAT (Velickovic et al., arXiv:1710.10903) -------------------------------


@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    arch: str = "gat"


def gat_init(key, cfg: GATConfig):
    layers = []
    d_in = cfg.d_in
    for i in range(cfg.n_layers):
        k1, k2, k3, key = jax.random.split(key, 4)
        heads = cfg.n_heads
        d_out = cfg.d_hidden if i < cfg.n_layers - 1 else cfg.n_classes
        layers.append({
            "w": dense_init(k1, d_in, heads * d_out),
            "a_src": dense_init(k2, d_out, heads).T,   # (heads, d_out)
            "a_dst": dense_init(k3, d_out, heads).T,
        })
        d_in = heads * d_out if i < cfg.n_layers - 1 else d_out
    return {"layers": layers}


def gat_apply(params, graph, cfg: GATConfig):
    x = graph["node_feat"]
    n = x.shape[0]
    snd, rcv = graph["senders"], graph["receivers"]
    emask = graph["edge_mask"]
    for i, lp in enumerate(params["layers"]):
        heads = cfg.n_heads
        d_out = lp["a_src"].shape[1]
        h = (x @ lp["w"]).reshape(n, heads, d_out)
        att_s = jnp.einsum("nhd,dh->nh", h, lp["a_src"].T)
        att_d = jnp.einsum("nhd,dh->nh", h, lp["a_dst"].T)
        logits = jax.nn.leaky_relu(att_s[snd] + att_d[rcv], 0.2)  # (E, H)
        alpha = jax.vmap(
            lambda lg: segment_softmax(lg, rcv, n, emask), in_axes=1,
            out_axes=1)(logits)
        msg = h[snd] * alpha[..., None]
        agg = jax.ops.segment_sum(
            jnp.where(emask[:, None, None], msg, 0.0), rcv, num_segments=n)
        if i < cfg.n_layers - 1:
            x = jax.nn.elu(agg).reshape(n, heads * d_out)
        else:
            x = agg.mean(axis=1)  # average heads on the output layer
    return x


# --- SchNet (Schutt et al., arXiv:1706.08566) --------------------------------


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    name: str = "schnet"
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_species: int = 32
    arch: str = "schnet"


def schnet_init(key, cfg: SchNetConfig):
    ks = jax.random.split(key, 3 + cfg.n_interactions)
    inter = []
    for i in range(cfg.n_interactions):
        k1, k2, k3 = jax.random.split(ks[3 + i], 3)
        inter.append({
            "filter": _mlp_init(k1, [cfg.n_rbf, cfg.d_hidden, cfg.d_hidden]),
            "in_dense": dense_init(k2, cfg.d_hidden, cfg.d_hidden),
            "out": _mlp_init(k3, [cfg.d_hidden, cfg.d_hidden, cfg.d_hidden]),
        })
    return {
        "embed": jax.random.normal(ks[0], (cfg.n_species, cfg.d_hidden)) * 0.1,
        "inter": inter,
        "readout": _mlp_init(ks[1], [cfg.d_hidden, cfg.d_hidden // 2, 1]),
    }


def _gaussian_rbf(d, n_rbf, cutoff):
    mu = jnp.linspace(0.0, cutoff, n_rbf)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * (d[:, None] - mu[None, :]) ** 2)


def schnet_apply(params, graph, cfg: SchNetConfig):
    """Returns per-graph energies (n_graphs,)."""
    species = graph["node_feat"].astype(jnp.int32).reshape(-1)
    pos = graph["positions"]
    snd, rcv = graph["senders"], graph["receivers"]
    emask = graph["edge_mask"]
    n = species.shape[0]
    h = params["embed"][jnp.clip(species, 0, cfg.n_species - 1)]
    dist = jnp.linalg.norm(pos[snd] - pos[rcv] + 1e-12, axis=-1)
    rbf = _gaussian_rbf(dist, cfg.n_rbf, cfg.cutoff)
    for lp in params["inter"]:
        w = _mlp(lp["filter"], rbf, act=shifted_softplus, final_act=True)
        hx = h @ lp["in_dense"]
        msg = jnp.where(emask[:, None], hx[snd] * w, 0.0)
        agg = jax.ops.segment_sum(msg, rcv, num_segments=n)
        h = h + _mlp(lp["out"], agg, act=shifted_softplus)
    atom_e = _mlp(params["readout"], h, act=shifted_softplus)[:, 0]
    atom_e = jnp.where(graph["node_mask"], atom_e, 0.0)
    return jax.ops.segment_sum(atom_e, graph["graph_ids"],
                               num_segments=graph["n_graphs"])


# --- MeshGraphNet (Pfaff et al., arXiv:2010.03409) ---------------------------


@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 3
    arch: str = "meshgraphnet"
    scan_unroll: int = 1         # roofline tooling: inline the layer scan


def _mgn_mlp_dims(cfg, d_in):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def mgn_init(key, cfg: MGNConfig):
    ks = jax.random.split(key, 4 + cfg.n_layers)
    d = cfg.d_hidden
    layers = []
    for i in range(cfg.n_layers):
        k1, k2 = jax.random.split(ks[4 + i])
        layers.append({
            "edge_mlp": _mlp_init(k1, _mgn_mlp_dims(cfg, 3 * d)),
            "node_mlp": _mlp_init(k2, _mgn_mlp_dims(cfg, 2 * d)),
        })
    # stack layer params for scan
    stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *layers)
    return {
        "node_enc": _mlp_init(ks[0], _mgn_mlp_dims(cfg, cfg.d_node_in)),
        "edge_enc": _mlp_init(ks[1], _mgn_mlp_dims(cfg, cfg.d_edge_in)),
        "layers": stacked,
        "decoder": _mlp_init(ks[2], [d, d, cfg.d_out]),
    }


def mgn_apply(params, graph, cfg: MGNConfig):
    """Returns per-node predictions (N, d_out)."""
    snd, rcv = graph["senders"], graph["receivers"]
    emask = graph["edge_mask"][:, None]
    n = graph["node_feat"].shape[0]
    h = _mlp(params["node_enc"], graph["node_feat"], final_act=True)
    e = _mlp(params["edge_enc"], graph["edge_feat"], final_act=True)

    def body(carry, lp):
        h, e = carry
        e_in = jnp.concatenate([e, h[snd], h[rcv]], axis=-1)
        e = e + _mlp(lp["edge_mlp"], e_in, final_act=True)
        agg = jax.ops.segment_sum(jnp.where(emask, e, 0.0), rcv,
                                  num_segments=n)
        h = h + _mlp(lp["node_mlp"],
                     jnp.concatenate([h, agg], axis=-1), final_act=True)
        return (h, e), None

    (h, e), _ = lax.scan(jax.checkpoint(body), (h, e), params["layers"],
                         unroll=cfg.scan_unroll)
    return _mlp(params["decoder"], h)


# --- DimeNet (Gasteiger et al., arXiv:2003.03123) ----------------------------


@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 32
    arch: str = "dimenet"
    # §Perf knobs: chunk the triplet gather (bounds the (T, b, d) live set)
    # and carry cross-shard messages in bf16 (halves gather collectives)
    triplet_chunks: int = 1
    msg_dtype: Any = jnp.float32


def dimenet_init(key, cfg: DimeNetConfig):
    ks = jax.random.split(key, 5 + cfg.n_blocks)
    d = cfg.d_hidden
    blocks = []
    n_sbf = cfg.n_spherical * cfg.n_radial
    for i in range(cfg.n_blocks):
        k1, k2, k3, k4, k5 = jax.random.split(ks[5 + i], 5)
        blocks.append({
            "w_rbf": dense_init(k1, cfg.n_radial, d),
            "w_sbf": dense_init(k2, n_sbf, cfg.n_bilinear),
            "w_kj": dense_init(k3, d, cfg.n_bilinear * d),
            "msg_mlp": _mlp_init(k4, [d, d, d]),
            "out_mlp": _mlp_init(k5, [d, d, d]),
        })
    return {
        "embed": jax.random.normal(ks[0], (cfg.n_species, d)) * 0.1,
        "edge_emb": _mlp_init(ks[1], [2 * d + cfg.n_radial, d]),
        "blocks": blocks,
        "out_rbf": dense_init(ks[2], cfg.n_radial, d),
        "readout": _mlp_init(ks[3], [d, d // 2, 1]),
    }


def _bessel_rbf(d, n_radial, cutoff):
    """Radial Bessel basis (DimeNet eq. 7): sin(n pi d / c) / d."""
    freq = jnp.pi * jnp.arange(1, n_radial + 1)
    dc = jnp.clip(d / cutoff, 1e-6, 1.0)
    return jnp.sqrt(2.0 / cutoff) * jnp.sin(freq * dc[:, None]) / \
        (dc[:, None] * cutoff)


def _angular_sbf(angle, d, n_spherical, n_radial, cutoff):
    """Simplified spherical basis: cos(l * angle) x Bessel(d) outer basis
    (the full 2D spherical Bessel solution is replaced by a separable
    Fourier x Bessel product — documented deviation, same tensor shapes)."""
    ang = jnp.cos(jnp.arange(n_spherical)[None, :] * angle[:, None])
    rad = _bessel_rbf(d, n_radial, cutoff)
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        angle.shape[0], n_spherical * n_radial)


def dimenet_apply(params, graph, cfg: DimeNetConfig):
    """Directional message passing; returns per-graph energies."""
    species = graph["node_feat"].astype(jnp.int32).reshape(-1)
    pos = graph["positions"]
    snd, rcv = graph["senders"], graph["receivers"]
    emask = graph["edge_mask"]
    t_kj, t_ji = graph["triplet_src"], graph["triplet_dst"]
    tmask = graph["triplet_mask"]
    n, e = species.shape[0], snd.shape[0]

    h = params["embed"][jnp.clip(species, 0, cfg.n_species - 1)]
    vec = pos[snd] - pos[rcv]
    dist = jnp.linalg.norm(vec + 1e-12, axis=-1)
    rbf = _bessel_rbf(dist, cfg.n_radial, cfg.cutoff)

    # initial directional messages m_ji
    m = _mlp(params["edge_emb"],
             jnp.concatenate([h[snd], h[rcv], rbf], axis=-1),
             act=shifted_softplus, final_act=True)

    # triplet angle between edge k->j and j->i
    v_kj = vec[t_kj]
    v_ji = vec[t_ji]
    cosang = jnp.sum(v_kj * v_ji, -1) / jnp.maximum(
        jnp.linalg.norm(v_kj, axis=-1) * jnp.linalg.norm(v_ji, axis=-1), 1e-9)
    angle = jnp.arccos(jnp.clip(cosang, -1 + 1e-7, 1 - 1e-7))
    sbf = _angular_sbf(angle, dist[t_kj], cfg.n_spherical, cfg.n_radial,
                       cfg.cutoff)

    d = cfg.d_hidden
    node_out = jnp.zeros((n, d))

    def triplet_agg(m, bp):
        """sum over k of bilinear(sbf, m_kj) scattered to edge ji; chunked
        over the triplet list when cfg.triplet_chunks > 1 (§Perf)."""
        mdt = cfg.msg_dtype
        t = t_kj.shape[0]
        nch = cfg.triplet_chunks if t % cfg.triplet_chunks == 0 else 1

        def one(chunk):
            kj, ji, msk, sb = chunk
            mk = (m.astype(mdt)[kj] @ bp["w_kj"].astype(mdt)).reshape(
                -1, cfg.n_bilinear, d)
            tr = jnp.einsum("tb,tbd->td", sb.astype(mdt), mk)
            tr = jnp.where(msk[:, None], tr, 0)
            return jax.ops.segment_sum(tr, ji, num_segments=e)

        if nch == 1:
            return one((t_kj, t_ji, tmask, sbf @ bp["w_sbf"]))
        sb_all = sbf @ bp["w_sbf"]
        chunks = (t_kj.reshape(nch, -1), t_ji.reshape(nch, -1),
                  tmask.reshape(nch, -1), sb_all.reshape(nch, -1,
                                                         cfg.n_bilinear))
        agg = lax.map(jax.checkpoint(one), chunks)
        return agg.sum(0)

    for bp in params["blocks"]:
        agg = triplet_agg(m, bp).astype(m.dtype)
        m = m + _mlp(bp["msg_mlp"], agg * (rbf @ bp["w_rbf"]),
                     act=shifted_softplus)
        # per-block output: edge->node
        contrib = jnp.where(emask[:, None], m * (rbf @ params["out_rbf"]), 0.0)
        hn = jax.ops.segment_sum(contrib, rcv, num_segments=n)
        node_out = node_out + _mlp(bp["out_mlp"], hn, act=shifted_softplus)

    atom_e = _mlp(params["readout"], node_out, act=shifted_softplus)[:, 0]
    atom_e = jnp.where(graph["node_mask"], atom_e, 0.0)
    return jax.ops.segment_sum(atom_e, graph["graph_ids"],
                               num_segments=graph["n_graphs"])


# --- unified entry points ----------------------------------------------------

ARCHS = {
    "gat": (GATConfig, gat_init, gat_apply),
    "schnet": (SchNetConfig, schnet_init, schnet_apply),
    "meshgraphnet": (MGNConfig, mgn_init, mgn_apply),
    "dimenet": (DimeNetConfig, dimenet_init, dimenet_apply),
}


def init_params(key, cfg):
    return ARCHS[cfg.arch][1](key, cfg)


def apply(params, graph, cfg):
    return ARCHS[cfg.arch][2](params, graph, cfg)


def loss_fn(params, graph, cfg):
    """Node classification (gat), node regression (meshgraphnet), or
    per-graph energy regression (schnet/dimenet)."""
    out = apply(params, graph, cfg)
    if cfg.arch == "gat":
        labels = graph["labels"]
        mask = graph["node_mask"] & (labels >= 0)
        logp = jax.nn.log_softmax(out, axis=-1)
        nll = -jnp.take_along_axis(logp, jnp.clip(labels, 0)[:, None],
                                   axis=1)[:, 0]
        loss = jnp.sum(jnp.where(mask, nll, 0.0)) / jnp.maximum(
            mask.sum(), 1)
        acc = jnp.sum(jnp.where(mask, jnp.argmax(out, -1) == labels, False)
                      ) / jnp.maximum(mask.sum(), 1)
        return loss, {"acc": acc}
    if cfg.arch == "meshgraphnet":
        err = (out - graph["labels"]) ** 2
        mask = graph["node_mask"][:, None]
        loss = jnp.sum(jnp.where(mask, err, 0.0)) / jnp.maximum(
            mask.sum() * out.shape[-1], 1)
        return loss, {}
    # energy models
    err = (out - graph["labels"]) ** 2
    return err.mean(), {}
