"""Substrate tests: optimizer, checkpoint/restore, fault-tolerant driver,
gradient compression, data pipelines."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (adamw, warmup_cosine, compressed_gradients,
                         int8_compress_decompress, topk_compress_decompress,
                         clip_by_global_norm)
from repro.checkpoint import CheckpointManager, save_pytree, load_pytree
from repro.runtime.driver import TrainDriver, InjectedFailure
from repro.data.tokens import TokenStream


def _quadratic_setup():
    target = jnp.asarray(np.random.default_rng(0).standard_normal(8),
                         dtype=jnp.float32)

    def loss(p):
        return jnp.sum((p["w"] - target) ** 2)

    params = {"w": jnp.zeros(8)}
    return loss, params, target


def test_adamw_converges():
    loss, params, target = _quadratic_setup()
    opt = adamw(0.1, weight_decay=0.0)
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, m = opt.update(g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_warmup_cosine_shape():
    fn = warmup_cosine(1.0, 10, 100)
    assert float(fn(jnp.int32(0))) == 0.0
    assert abs(float(fn(jnp.int32(10))) - 1.0) < 1e-6
    assert float(fn(jnp.int32(100))) <= 0.11
    assert float(fn(jnp.int32(5))) == pytest.approx(0.5)


def test_grad_clip():
    tree = {"a": jnp.ones(4) * 10.0}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_int8_roundtrip_small_error():
    g = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    dtype=jnp.float32)
    out = int8_compress_decompress(g)
    rel = float(jnp.linalg.norm(out - g) / jnp.linalg.norm(g))
    assert rel < 0.02


def test_topk_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out, mass = topk_compress_decompress(g, frac=0.4)
    np.testing.assert_allclose(np.asarray(out), [0, -5.0, 0, 3.0, 0])
    assert float(mass) > 0.99


def test_error_feedback_conserves_signal():
    """EF invariant: sum(compressed outputs) + residual == sum(inputs) —
    nothing the codec drops is ever lost, it is replayed later."""
    rng = np.random.default_rng(2)
    ef = None
    total = jnp.zeros(16)
    gsum = jnp.zeros(16)
    for i in range(20):
        g = {"w": jnp.asarray(rng.standard_normal(16), dtype=jnp.float32)}
        gsum = gsum + g["w"]
        comp, ef = compressed_gradients(g, ef, codec="topk", topk_frac=0.25)
        total = total + comp["w"]
    np.testing.assert_allclose(np.asarray(total + ef.residual["w"]),
                               np.asarray(gsum), atol=1e-4)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.ones(4, jnp.int32)}}
    path = str(tmp_path / "ck")
    save_pytree(path, tree, step=7)
    out = load_pytree(path, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["b"]["c"]),
                                  np.asarray(tree["b"]["c"]))


def test_checkpoint_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_write=False)
    tree = {"w": jnp.zeros(3)}
    for s in (10, 20, 30):
        mgr.save(s, tree)
    assert mgr.steps() == [20, 30]
    assert mgr.latest_step() == 30


def test_driver_restart_resumes_exactly(tmp_path):
    """Inject a failure; the driver must restore and produce bit-identical
    final state vs an uninterrupted run (deterministic data + seek)."""
    loss, params0, target = _quadratic_setup()
    opt = adamw(0.05, weight_decay=0.0)

    def step_fn(state, batch):
        params, ostate = state
        g = jax.grad(loss)(params)
        g = jax.tree.map(lambda x: x + batch["noise"], g)
        params, ostate, m = opt.update(g, ostate, params)
        return (params, ostate), {"loss": loss(params)}

    def make_data(start):
        def gen():
            step = start
            while True:
                rng = np.random.default_rng(step)
                yield {"noise": jnp.float32(rng.standard_normal() * 0.01)}
                step += 1
        return gen()

    def run(inject, subdir):
        mgr = CheckpointManager(str(tmp_path / subdir), keep_last=3,
                                async_write=False)
        fail = {"armed": inject}

        def injector(step):
            if fail["armed"] and step == 33:
                fail["armed"] = False
                return True
            return False

        drv = TrainDriver(step_fn=step_fn,
                          init_state=(params0, opt.init(params0)),
                          make_data=make_data, ckpt=mgr, ckpt_every=10,
                          failure_injector=injector, log_every=0,
                          verbose=False)
        state, report = drv.run(50)
        return state, report

    clean, rep0 = run(False, "clean")
    faulty, rep1 = run(True, "faulty")
    assert rep0["restarts"] == 0
    assert rep1["restarts"] == 1
    np.testing.assert_allclose(np.asarray(clean[0]["w"]),
                               np.asarray(faulty[0]["w"]), atol=1e-7)


def test_token_stream_seekable():
    a = TokenStream(64, 4, 16, seed=3)
    batches = [next(a) for _ in range(5)]
    b = TokenStream(64, 4, 16, seed=3, start_step=3)
    np.testing.assert_array_equal(batches[3]["tokens"], next(b)["tokens"])
    np.testing.assert_array_equal(batches[4]["labels"], next(b)["labels"])


def test_token_stream_learnable_structure():
    s = TokenStream(16, 8, 32, seed=0)
    b = next(s)
    # 80% of transitions follow the planted permutation
    perm = s.perm
    hits = (perm[b["tokens"]] == b["labels"]).mean()
    assert hits > 0.6
