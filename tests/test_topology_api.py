"""Facade parity contract (DESIGN.md §Serve): every `repro.topology.submit`
route must be bit-identical to the legacy entry point it fronts, on the
same ragged seed corpus the pad-and-mask harness uses; the legacy names in
`repro.core` must still work but emit DeprecationWarning; and the two stats
tuples must stay field-for-field reconciled.

Distributed routes are covered in-subprocess by tests/test_serve_engine.py
(same 8-fake-device worker pattern); here the pure routes run in-process.
"""
import warnings

import numpy as np
import pytest

from oracles import (GRID_SEED_CORPUS, GRAPH_SEED_CORPUS,
                     ragged_grid_case, ragged_graph_case)

import jax.numpy as jnp

from repro.topology import TopologyRequest, submit, submit_many
from repro.core.connected_components import (connected_components_grid,
                                             connected_components_graph)
from repro.core.ms_segmentation import (ms_segmentation,
                                        ms_segmentation_graph,
                                        descending_manifold,
                                        ascending_manifold)
from repro.core.ids import compute_order


def _grid_case(seed):
    shape, _, conn, mask_p = ragged_grid_case(seed)
    rng = np.random.default_rng(1000 + seed)
    mask = rng.random(shape) < mask_p
    field = rng.standard_normal(shape)
    return shape, conn, jnp.asarray(mask), jnp.asarray(field)


# --- pure-route parity on the ragged corpus ----------------------------------


@pytest.mark.parametrize("seed", GRID_SEED_CORPUS)
def test_cc_grid_pure_parity(seed):
    _, conn, mask, _ = _grid_case(seed)
    legacy = connected_components_grid(mask, conn)
    res = submit(TopologyRequest("cc", mask=mask, connectivity=conn))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(legacy.labels))
    assert res.meta["n_rounds"] == legacy.n_rounds


@pytest.mark.parametrize("seed", GRAPH_SEED_CORPUS)
def test_cc_graph_pure_parity(seed):
    _, s, r, _, _, mask = ragged_graph_case(seed)
    m, s, r = jnp.asarray(mask), jnp.asarray(s), jnp.asarray(r)
    legacy = connected_components_graph(m, s, r)
    res = submit(TopologyRequest("cc", domain="graph", mask=m,
                                 senders=s, receivers=r))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(legacy.labels))


@pytest.mark.parametrize("seed", GRID_SEED_CORPUS[:4])
def test_ms_and_manifold_grid_pure_parity(seed):
    _, conn, _, field = _grid_case(seed)
    order = compute_order(field)
    legacy = ms_segmentation(order, conn)
    res = submit(TopologyRequest("ms", order=order, connectivity=conn))
    np.testing.assert_array_equal(np.asarray(res.segmentation),
                                  np.asarray(legacy.segmentation))
    np.testing.assert_array_equal(np.asarray(res.ascending),
                                  np.asarray(legacy.ascending))

    for descending, fn in ((True, descending_manifold),
                           (False, ascending_manifold)):
        lab, _ = fn(order, conn)
        got = submit(TopologyRequest("manifold", order=order,
                                     connectivity=conn,
                                     descending=descending))
        np.testing.assert_array_equal(np.asarray(got.labels).ravel(),
                                      np.asarray(lab).ravel())


@pytest.mark.parametrize("seed", GRAPH_SEED_CORPUS[:4])
def test_ms_graph_pure_parity(seed):
    n, s, r, _, _, _ = ragged_graph_case(seed)
    rng = np.random.default_rng(2000 + seed)
    order = compute_order(jnp.asarray(rng.standard_normal(n)))
    legacy = ms_segmentation_graph(order, jnp.asarray(s), jnp.asarray(r))
    res = submit(TopologyRequest("ms", domain="graph", order=order,
                                 senders=jnp.asarray(s),
                                 receivers=jnp.asarray(r)))
    np.testing.assert_array_equal(np.asarray(res.segmentation),
                                  np.asarray(legacy.segmentation))


@pytest.mark.parametrize("seed", GRID_SEED_CORPUS[:4])
def test_threshold_sweep_pure_is_sequential_ccs(seed):
    """The vmapped sweep == K independent legacy CC calls, grid and graph."""
    _, conn, _, field = _grid_case(seed)
    thr = np.quantile(np.asarray(field), [0.25, 0.5, 0.75])
    res = submit(TopologyRequest("threshold_sweep", field=field,
                                 thresholds=jnp.asarray(thr),
                                 connectivity=conn))
    assert res.labels.shape == (3,) + field.shape
    for k, t in enumerate(thr):
        legacy = connected_components_grid(field > t, conn)
        np.testing.assert_array_equal(np.asarray(res.labels[k]),
                                      np.asarray(legacy.labels))

    n, s, r, _, _, _ = ragged_graph_case(seed)
    rng = np.random.default_rng(3000 + seed)
    gfield = jnp.asarray(rng.standard_normal(n))
    gthr = np.quantile(np.asarray(gfield), [0.3, 0.7])
    res = submit(TopologyRequest("threshold_sweep", domain="graph",
                                 field=gfield, thresholds=jnp.asarray(gthr),
                                 senders=jnp.asarray(s),
                                 receivers=jnp.asarray(r)))
    for k, t in enumerate(gthr):
        legacy = connected_components_graph(gfield > t, jnp.asarray(s),
                                            jnp.asarray(r))
        np.testing.assert_array_equal(np.asarray(res.labels[k]),
                                      np.asarray(legacy.labels))


def test_submit_many_keeps_order_and_tags():
    _, conn, mask, field = _grid_case(0)
    reqs = [TopologyRequest("cc", mask=mask, connectivity=conn, tag="a"),
            TopologyRequest("ms", order=compute_order(field),
                            connectivity=conn, tag="b")]
    out = submit_many(reqs)
    assert [r.tag for r in out] == ["a", "b"]
    assert [r.query for r in out] == ["cc", "ms"]


# --- request validation ------------------------------------------------------


def test_request_validation_errors():
    with pytest.raises(ValueError, match="query"):
        submit(TopologyRequest("nope", mask=jnp.zeros((2, 2), bool)))
    with pytest.raises(ValueError, match="needs mask"):
        submit(TopologyRequest("cc"))
    with pytest.raises(ValueError, match="senders"):
        submit(TopologyRequest("cc", domain="graph",
                               mask=jnp.zeros(4, bool)))
    with pytest.raises(ValueError, match="mesh"):
        submit(TopologyRequest("cc", backend="distributed",
                               mask=jnp.zeros((2, 2), bool)))
    with pytest.raises(NotImplementedError):
        submit(TopologyRequest("manifold", domain="graph",
                               order=jnp.arange(4),
                               senders=jnp.array([0]),
                               receivers=jnp.array([1])))


# --- legacy names: working deprecation shims ---------------------------------


def test_legacy_core_names_warn_and_forward():
    import repro.core as core
    mask = jnp.asarray(np.eye(5, dtype=bool))
    with pytest.warns(DeprecationWarning, match="repro.topology"):
        legacy = core.connected_components_grid(mask, 4)
    res = submit(TopologyRequest("cc", mask=mask, connectivity=4))
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(legacy.labels))


def test_facade_path_does_not_warn():
    """Internal modules import submodules directly, so the facade and the
    engine never trip their own deprecation layer."""
    mask = jnp.asarray(np.eye(5, dtype=bool))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        submit(TopologyRequest("cc", mask=mask, connectivity=4))


# --- stats reconciliation ----------------------------------------------------


def test_stats_tuples_reconciled():
    from repro.core.stats import (STAT_FIELDS, DPCStats, GraphDPCStats,
                                  stats_as_dict)
    assert DPCStats._fields == STAT_FIELDS
    assert GraphDPCStats._fields == STAT_FIELDS
    vals = {f: jnp.asarray(i) for i, f in enumerate(STAT_FIELDS)}
    for cls in (DPCStats, GraphDPCStats):
        d = cls(**vals).as_dict()
        assert tuple(d) == STAT_FIELDS
        assert d["comm_phases"] == STAT_FIELDS.index("comm_phases")
    batched = DPCStats(**{f: jnp.full((3,), i)
                          for i, f in enumerate(STAT_FIELDS)})
    d = stats_as_dict(batched)
    assert d["stitch_rounds"] == [STAT_FIELDS.index("stitch_rounds")] * 3
