"""Core library: Distributed Path Compression (Will et al., CS.DC 2024).

The seven historical query entry points (`connected_components_grid`,
`connected_components_graph`, `ms_segmentation`, `ms_segmentation_graph`,
`distributed_manifold`, `distributed_connected_components`,
`distributed_connected_components_graph`) are superseded by the unified
`repro.topology` facade (`TopologyRequest` / `TopologyResult` / `submit`)
and remain here as thin deprecation shims — bit-identical behaviour, plus a
`DeprecationWarning` pointing at the facade route that replaces them.
"""
import functools
import warnings

from .ids import compute_order, inverse_permutation, flat_ids, compact_labels
from .pathcompress import (path_compress, path_compress_unrolled, jump,
                           is_converged)
from .steepest import (grid_steepest, grid_mask_argmax, graph_steepest,
                       graph_mask_argmax, neighbor_offsets, shift_fill)
from . import ms_segmentation as _ms
from .ms_segmentation import (descending_manifold, ascending_manifold,
                              extrema, MSSegmentation)
from . import connected_components as _cc
from .connected_components import component_sizes, CCResult
from .baseline_cc import label_propagation_grid, extract_masked_edges
from . import distributed as _dist
from .distributed import (distributed_manifold_batch,
                          distributed_connected_components_batch,
                          make_dpc_mesh, BlockDecomp, AXIS, BLOCK_AXES)
from . import distributed_graph as _dist_graph
from .distributed_graph import (distributed_connected_components_graph_batch,
                                GraphDecomp)
from .stats import DPCStats, GraphDPCStats, STAT_FIELDS, stats_as_dict


def _facade_shim(fn, route):
    """Wrap a legacy query entry point: same behaviour, plus a
    DeprecationWarning naming the `repro.topology` route that replaces it."""
    @functools.wraps(fn)
    def shim(*args, **kwargs):
        warnings.warn(
            f"repro.core.{fn.__name__} is deprecated as a public entry "
            f"point; submit repro.topology.TopologyRequest({route}) via "
            "repro.topology.submit (or the batched repro.serve engine) "
            "instead — the legacy call stays bit-identical underneath",
            DeprecationWarning, stacklevel=2)
        return fn(*args, **kwargs)
    return shim


connected_components_grid = _facade_shim(
    _cc.connected_components_grid,
    "query='cc', domain='grid', backend='pure'")
connected_components_graph = _facade_shim(
    _cc.connected_components_graph,
    "query='cc', domain='graph', backend='pure'")
ms_segmentation = _facade_shim(
    _ms.ms_segmentation,
    "query='ms', domain='grid', backend='pure'")
ms_segmentation_graph = _facade_shim(
    _ms.ms_segmentation_graph,
    "query='ms', domain='graph', backend='pure'")
distributed_manifold = _facade_shim(
    _dist.distributed_manifold,
    "query='manifold', domain='grid', backend='distributed'")
distributed_connected_components = _facade_shim(
    _dist.distributed_connected_components,
    "query='cc', domain='grid', backend='distributed'")
distributed_connected_components_graph = _facade_shim(
    _dist_graph.distributed_connected_components_graph,
    "query='cc', domain='graph', backend='distributed'")

__all__ = [
    "compute_order", "inverse_permutation", "flat_ids", "compact_labels",
    "path_compress", "path_compress_unrolled", "jump", "is_converged",
    "grid_steepest", "grid_mask_argmax", "graph_steepest", "graph_mask_argmax",
    "neighbor_offsets", "shift_fill",
    "ms_segmentation", "ms_segmentation_graph", "descending_manifold",
    "ascending_manifold", "extrema", "MSSegmentation",
    "connected_components_grid", "connected_components_graph",
    "component_sizes", "CCResult",
    "label_propagation_grid", "extract_masked_edges",
    "distributed_manifold", "distributed_connected_components",
    "distributed_manifold_batch", "distributed_connected_components_batch",
    "make_dpc_mesh", "BlockDecomp", "DPCStats", "AXIS", "BLOCK_AXES",
    "distributed_connected_components_graph",
    "distributed_connected_components_graph_batch",
    "GraphDecomp", "GraphDPCStats", "STAT_FIELDS", "stats_as_dict",
]
