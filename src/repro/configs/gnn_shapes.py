"""Shared GNN-family shape set.  Sizes are the assigned cells; sampled
shapes (minibatch_lg) list both the source-graph size and the padded
per-batch sample sizes the sampler guarantees."""

SHAPES = {
    "full_graph_sm": {
        "kind": "full", "n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
        "n_classes": 7,
    },
    "minibatch_lg": {
        "kind": "sampled", "n_nodes": 232_965, "n_edges": 114_615_892,
        "batch_nodes": 1024, "fanout": (15, 10), "d_feat": 602,
        "n_classes": 41,
        # padded sample sizes: 1024*(1+15+150) nodes, 2*1024*(15+150) edges
        "sample_nodes": 169_984, "sample_edges": 337_920,
    },
    "ogb_products": {
        "kind": "full", "n_nodes": 2_449_029, "n_edges": 61_859_140,
        "d_feat": 100, "n_classes": 47,
    },
    "molecule": {
        "kind": "batched", "n_nodes": 30, "n_edges": 64, "batch": 128,
    },
}

# smoke shapes are multiples of 512 on sharded dims so `dryrun --smoke`
# exercises the identical sharding paths on the production meshes
SMOKE_SHAPES = {
    "full_graph_sm": {"kind": "full", "n_nodes": 1024, "n_edges": 4096,
                      "d_feat": 16, "n_classes": 7},
    "minibatch_lg": {"kind": "sampled", "n_nodes": 2048, "n_edges": 16384,
                     "batch_nodes": 128, "fanout": (3, 2), "d_feat": 16,
                     "n_classes": 7, "sample_nodes": 1536,
                     "sample_edges": 2048},
    "ogb_products": {"kind": "full", "n_nodes": 1024, "n_edges": 4096,
                     "d_feat": 16, "n_classes": 7},
    "molecule": {"kind": "batched", "n_nodes": 16, "n_edges": 32,
                 "batch": 64},
}
