"""Pallas TPU kernel: fused block-local phase (init + in-VMEM saturation).

The block-local phase of Alg. 1/3 is DPC's hot path, and running it as
`grid_steepest` followed by a global `d <- d[d]` while-loop costs one full
HBM round-trip per doubling round, with the extended block materialised
between init and first compression.  This kernel fuses both: per
VMEM-resident x-slab it

  1. computes the pointer init directly from the order field (steepest
     argmax, ``mode="manifold"``) or the feature mask (largest masked
     neighbor id, ``mode="cc"``), reusing the pre-sliced halo-plane layout
     of `steepest_neighbor` (no overlapping BlockSpecs);
  2. applies the optional ``self_mask`` override in-register (distributed
     ghost vertices pretend to be maxima, Alg. 1 lines 6-8);
  3. runs the pointer-doubling saturation loop *inside the tile* until the
     tile is locally converged (the on-device saturation-loop idiom of the
     GPU Morse-Smale pipeline, arXiv 2009.03707).

Out-of-tile and sentinel (-1) pointers are fixed points, so the tile
boundary is a ghost boundary and correctness follows from the distributed
algorithm's own argument (DESIGN.md §Perf): the fixpoint of pointer chasing
is invariant under restricted jumps, and the remaining *global* doubling
loop starts near-converged.  One HBM read + one write per voxel buys all
intra-tile rounds.

Slab extents need not divide the tile: the x axis is padded up to the tile
grid with an inert fill (order ``iinfo.min`` / mask ``False``) that can
never win an argmax, so pad rows self-point and are sliced back off
(pad-and-mask, deviation (p) in DESIGN.md).

Returns ``(pointers, rounds)``: pointers are flat ids of the input array
(same local-id convention as `grid_steepest`; ``-1`` for unmasked CC
vertices), rounds is the max in-tile saturation round count over slabs —
surfaced as ``DPCStats.kernel_rounds`` by the distributed entry points.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.steepest import neighbor_offsets

# connectivities with a 3-D offset table (the kernel is 3-D only; ops.py
# dispatches every other case to the jnp fallback)
KERNEL_CONNECTIVITIES = (6, 14, 18, 26)


def _shifted(a, off, fill_val):
    """a[p + off] within the tile, fill outside (static shifts)."""
    pads = [(max(-o, 0), max(o, 0)) for o in off]
    padded = jnp.pad(a, pads, constant_values=fill_val)
    sl = tuple(slice(max(o, 0), max(o, 0) + s)
               for o, s in zip(off, a.shape))
    return padded[sl]


def _kernel(center, lo, hi, *rest, offsets, block_x, R, fill, mode,
            max_rounds, id_dtype, has_self_mask, n_real):
    if has_self_mask:
        smask_ref, out_ref, rounds_ref = rest
    else:
        out_ref, rounds_ref = rest
    i = pl.program_id(0)
    ext = jnp.concatenate([lo[...], center[...], hi[...]], axis=0)
    z = ext.shape[2]
    # flat ids of the extended tile in the (padded) input array (row-major,
    # x-major layout); the lo plane sits at global x = i*block_x - 1
    base = (i * block_x - 1).astype(id_dtype) * R
    gids = base + jax.lax.broadcasted_iota(id_dtype, ext.shape, 0) * R \
        + jax.lax.broadcasted_iota(id_dtype, ext.shape, 1) * z \
        + jax.lax.broadcasted_iota(id_dtype, ext.shape, 2)

    minus1 = jnp.asarray(-1, id_dtype)
    if mode == "manifold":
        # stacked candidates + ONE argmax, not a chain of per-offset selects
        # — the chained-where form sends XLA:CPU fusion into minutes-long
        # compiles at connectivity >= 14 (same pathology grid_steepest works
        # around).  Self is candidate 0, so argmax's first-max-wins tie rule
        # keeps self on ties, which only occur at the inert fill value.
        cand_val = jnp.stack([ext] + [_shifted(ext, off, fill)
                                      for off in offsets])
        cand_idx = jnp.stack([gids] + [_shifted(gids, off, minus1)
                                       for off in offsets])
        choice = jnp.argmax(cand_val, axis=0)
        ptr = jnp.take_along_axis(cand_idx, choice[None], axis=0)[0][1:-1]
        # ragged-pad rows (ids past the real extent) sit BELOW every real
        # order value, so they'd point into the real region and burn chase
        # rounds; pin them to self — inert fixed points, sliced off outside
        own = gids[1:-1]
        ptr = jnp.where(own < n_real, ptr, own)
        masked = None
    else:  # "cc": largest masked neighbor id (incl. self), -1 unmasked
        key = jnp.where(ext != 0, gids, minus1)
        best = key
        for off in offsets:
            best = jnp.maximum(best, _shifted(key, off, minus1))
        masked = ext[1:-1] != 0
        ptr = jnp.where(masked, best[1:-1], minus1)

    if has_self_mask:
        # ghost override: (masked) ghosts pretend to be maxima / roots
        keep = smask_ref[...] != 0
        if masked is not None:
            keep = keep & masked
        ptr = jnp.where(keep, gids[1:-1], ptr)

    # in-tile saturation: doubling rounds confined to this slab's id range;
    # out-of-tile and negative pointers are fixed points (ghost boundary)
    tsize = block_x * R
    base_c = (i * block_x).astype(id_dtype) * R
    d0 = ptr.reshape(-1)

    def cond(state):
        _, changed, r = state
        return changed & (r < max_rounds)

    def body(state):
        d, _, r = state
        local = d - base_c
        in_tile = (d >= 0) & (local >= 0) & (local < tsize)
        idx = jnp.clip(local, 0, tsize - 1).astype(jnp.int32)
        nd = jnp.take(d, idx, axis=0)
        nxt = jnp.where(in_tile, nd, d)
        return nxt, jnp.any(nxt != d), r + jnp.int32(1)

    d, _, rounds = lax.while_loop(
        cond, body, (d0, jnp.asarray(True), jnp.int32(0)))
    out_ref[...] = d.reshape(ptr.shape)
    rounds_ref[...] = jnp.full((1,), rounds, jnp.int32)


@functools.partial(jax.jit, static_argnames=("connectivity", "mode",
                                             "block_x", "interpret",
                                             "id_dtype"))
def fused_local_phase(field: jax.Array, connectivity: int = 6,
                      mode: str = "manifold", self_mask=None,
                      block_x: int = 8, interpret: bool = True,
                      id_dtype=None):
    """Fused steepest/mask-argmax init + in-tile saturation per x-slab.

    field: (X, Y, Z) int order field (``mode="manifold"``; unique values,
    any inert fill strictly below them) or bool/int feature mask
    (``mode="cc"``).  self_mask: optional (X, Y, Z) bool — positions forced
    to self-pointers in the init (the distributed ghost layer).  Returns
    ((X, Y, Z) flat-id pointers, int32 max in-tile rounds).
    """
    if field.ndim != 3:
        raise ValueError(
            f"fused_local_phase is a 3-D x-slab kernel; got a {field.ndim}-D "
            f"field of shape {field.shape} — use the jnp fallback in "
            "repro.kernels.ops (impl='ref'), which dispatches it for you")
    if connectivity not in KERNEL_CONNECTIVITIES:
        raise ValueError(
            f"fused_local_phase supports 3-D connectivities "
            f"{KERNEL_CONNECTIVITIES}, got {connectivity}")
    if mode not in ("manifold", "cc"):
        raise ValueError(f"mode must be 'manifold' or 'cc', got {mode!r}")
    x, y, z = field.shape
    if id_dtype is None:
        id_dtype = jnp.int32 if field.size < 2**31 else jnp.int64
    if id_dtype == jnp.int64 and not jax.config.jax_enable_x64:
        raise ValueError("int64 pointer ids require jax_enable_x64 "
                         "(ids would silently wrap to int32)")

    if mode == "manifold":
        key = field
        fill = jnp.iinfo(field.dtype).min
    else:
        key = field.astype(jnp.int32)   # 0/1 mask; fill 0 = unmasked
        fill = 0

    # ragged x extent: pad up to the tile grid with the inert fill — pad
    # rows self-point (fill never wins an argmax) and are sliced back off
    n_tiles = -(-x // block_x)
    x_pad = n_tiles * block_x
    if x_pad != x:
        key = jnp.pad(key, [(0, x_pad - x), (0, 0), (0, 0)],
                      constant_values=fill)
    # pre-sliced halo planes: lo[i] = key[i*bx - 1], hi[i] = key[(i+1)*bx]
    padded = jnp.concatenate([
        jnp.full((1, y, z), fill, key.dtype), key,
        jnp.full((1, y, z), fill, key.dtype)], axis=0)
    lo = padded[0::block_x][:n_tiles]
    hi = padded[block_x + 1::block_x][:n_tiles]

    operands = [key, lo, hi]
    in_specs = [
        pl.BlockSpec((block_x, y, z), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, y, z), lambda i: (i, 0, 0)),
        pl.BlockSpec((1, y, z), lambda i: (i, 0, 0)),
    ]
    if self_mask is not None:
        sm = self_mask.astype(jnp.int32)
        if x_pad != x:
            sm = jnp.pad(sm, [(0, x_pad - x), (0, 0), (0, 0)])
        operands.append(sm)
        in_specs.append(pl.BlockSpec((block_x, y, z), lambda i: (i, 0, 0)))

    tsize = block_x * y * z
    # chain <= tile size, doubling resolves it in ceil(log2) rounds, plus
    # the final no-change verification round
    max_rounds = max((tsize - 1).bit_length(), 1) + 1
    kernel = functools.partial(
        _kernel, offsets=neighbor_offsets(3, connectivity), block_x=block_x,
        R=y * z, fill=fill, mode=mode, max_rounds=max_rounds,
        id_dtype=id_dtype, has_self_mask=self_mask is not None,
        n_real=x * y * z)
    ptr, rounds = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=in_specs,
        out_specs=[pl.BlockSpec((block_x, y, z), lambda i: (i, 0, 0)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((x_pad, y, z), id_dtype),
                   jax.ShapeDtypeStruct((n_tiles,), jnp.int32)],
        interpret=interpret,
    )(*operands)
    if x_pad != x:
        ptr = ptr[:x]
    return ptr, jnp.max(rounds)
