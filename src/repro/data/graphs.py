"""Graph data pipeline: synthetic datasets, CSR neighbor sampler (the
minibatch_lg requirement), fixed-shape GraphBatch construction, DimeNet
triplet lists — and DPC integration: every batch can be component-labeled
with the paper's algorithm (core.connected_components_graph) for pipeline
sanity checks and partition-aware reordering."""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


# --- synthetic graphs --------------------------------------------------------


def grid_edge_list(shape, connectivity: int):
    """Edge list of a structured grid's implicit triangulation, emitted as an
    unstructured mesh: with connectivity 14 on a 3-D shape this is exactly
    the edge set of the Kuhn/Freudenthal tetrahedralization (TTK's implicit
    triangulation), i.e. a synthetic tet-mesh-style edge list for the
    distributed graph-CC path.  Returns (senders, receivers) with BOTH
    directions of every undirected edge (the repo-wide graph convention).
    """
    from repro.core.steepest import neighbor_offsets
    offs = neighbor_offsets(len(shape), connectivity)
    idx = np.arange(int(np.prod(shape)), dtype=np.int64).reshape(shape)
    send, recv = [], []
    for off in offs:
        src_sl, dst_sl = [], []
        for o, sz in zip(off, shape):
            if o >= 0:
                src_sl.append(slice(0, sz - o))
                dst_sl.append(slice(o, sz))
            else:
                src_sl.append(slice(-o, sz))
                dst_sl.append(slice(0, sz + o))
        send.append(idx[tuple(src_sl)].ravel())
        recv.append(idx[tuple(dst_sl)].ravel())
    return np.concatenate(send), np.concatenate(recv)


def random_csr(n_nodes: int, avg_degree: int, seed: int = 0):
    """Undirected random graph in CSR form (deterministic)."""
    rng = np.random.default_rng(seed)
    m = n_nodes * avg_degree // 2
    src = rng.integers(0, n_nodes, m)
    dst = rng.integers(0, n_nodes, m)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    s = np.concatenate([src, dst])
    d = np.concatenate([dst, src])
    order = np.argsort(s, kind="stable")
    s, d = s[order], d[order]
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, s + 1, 1)
    indptr = np.cumsum(indptr)
    return indptr, d.astype(np.int32)


def cora_like(seed: int = 0, n_nodes: int = 2708, n_edges: int = 10556,
              d_feat: int = 1433, n_classes: int = 7):
    """Synthetic stand-in with cora's exact shape (full_graph_sm cell)."""
    rng = np.random.default_rng(seed)
    m = n_edges // 2
    src = rng.integers(0, n_nodes, m).astype(np.int32)
    dst = rng.integers(0, n_nodes, m).astype(np.int32)
    senders = np.concatenate([src, dst])
    receivers = np.concatenate([dst, src])
    feat = (rng.random((n_nodes, d_feat)) < 0.012).astype(np.float32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    return {
        "node_feat": feat, "senders": senders, "receivers": receivers,
        "node_mask": np.ones(n_nodes, bool),
        "edge_mask": np.ones(len(senders), bool),
        "labels": labels, "graph_ids": np.zeros(n_nodes, np.int32),
        "n_graphs": 1,
    }


def molecule_batch(batch: int = 128, n_nodes: int = 30, n_edges: int = 64,
                   n_species: int = 16, seed: int = 0,
                   max_triplets_per_graph: int | None = None):
    """Batched small molecules (the `molecule` cell): radius-graph edges,
    per-graph energy targets, DimeNet triplet lists."""
    rng = np.random.default_rng(seed)
    N, E = batch * n_nodes, batch * n_edges
    pos = rng.standard_normal((batch, n_nodes, 3)).astype(np.float32) * 2.0
    species = rng.integers(0, n_species, (batch, n_nodes)).astype(np.int32)
    senders = np.zeros((batch, n_edges), np.int32)
    receivers = np.zeros((batch, n_edges), np.int32)
    for b in range(batch):
        d = np.linalg.norm(pos[b][:, None] - pos[b][None, :], axis=-1)
        np.fill_diagonal(d, np.inf)
        flat = np.argsort(d.ravel())[:n_edges]
        senders[b] = (flat // n_nodes).astype(np.int32)
        receivers[b] = (flat % n_nodes).astype(np.int32)
    offs = (np.arange(batch) * n_nodes).astype(np.int32)
    senders = (senders + offs[:, None]).ravel()
    receivers = (receivers + offs[:, None]).ravel()
    energy = rng.standard_normal(batch).astype(np.float32)
    t_src, t_dst, t_mask = build_triplets(
        senders, receivers, N,
        max_triplets=batch * (max_triplets_per_graph or 4 * n_edges))
    return {
        "node_feat": species.reshape(-1, 1).astype(np.float32),
        "positions": pos.reshape(-1, 3),
        "senders": senders, "receivers": receivers,
        "node_mask": np.ones(N, bool), "edge_mask": np.ones(E, bool),
        "graph_ids": np.repeat(np.arange(batch, dtype=np.int32), n_nodes),
        "n_graphs": batch, "labels": energy,
        "triplet_src": t_src, "triplet_dst": t_dst, "triplet_mask": t_mask,
    }


def build_triplets(senders, receivers, n_nodes, max_triplets: int):
    """DimeNet edge-pair lists: all (k->j, j->i) with k != i.  Padded to
    `max_triplets`; pad entries point at edge 0 with mask=0."""
    e = len(senders)
    # edges grouped by their *sender* j give the k->j ... wait: incoming edges
    # of j are (k->j); outgoing are (j->i).  Group incoming by j:
    in_by_node = [[] for _ in range(n_nodes)]
    for idx in range(e):
        in_by_node[receivers[idx]].append(idx)
    t_src, t_dst = [], []
    for ji in range(e):
        j = senders[ji]
        for kj in in_by_node[j]:
            if senders[kj] != receivers[ji]:  # k != i
                t_src.append(kj)
                t_dst.append(ji)
                if len(t_src) >= max_triplets:
                    break
        if len(t_src) >= max_triplets:
            break
    t = len(t_src)
    pad = max_triplets - t
    src = np.array(t_src + [0] * pad, np.int32)
    dst = np.array(t_dst + [0] * pad, np.int32)
    mask = np.array([True] * t + [False] * pad)
    return src, dst, mask


def mesh_grid_graph(nx: int, ny: int, seed: int = 0, d_node_in: int = 8,
                    d_edge_in: int = 4, d_out: int = 3):
    """Regular triangulated mesh for MeshGraphNet smoke/bench runs."""
    rng = np.random.default_rng(seed)
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    half_s = np.concatenate([idx[:-1, :].ravel(), idx[:, :-1].ravel(),
                             idx[:-1, :-1].ravel()])
    half_r = np.concatenate([idx[1:, :].ravel(), idx[:, 1:].ravel(),
                             idx[1:, 1:].ravel()])
    send = np.concatenate([half_s, half_r]).astype(np.int32)
    recv = np.concatenate([half_r, half_s]).astype(np.int32)
    e = len(send)
    return {
        "node_feat": rng.standard_normal((n, d_node_in)).astype(np.float32),
        "edge_feat": rng.standard_normal((e, d_edge_in)).astype(np.float32),
        "senders": send, "receivers": recv,
        "node_mask": np.ones(n, bool), "edge_mask": np.ones(e, bool),
        "labels": rng.standard_normal((n, d_out)).astype(np.float32),
        "graph_ids": np.zeros(n, np.int32), "n_graphs": 1,
    }


# --- neighbor sampler (minibatch_lg) ------------------------------------------


@dataclasses.dataclass
class NeighborSampler:
    """Uniform fanout sampler over a CSR graph (GraphSAGE-style), producing
    fixed-shape padded subgraph batches for jit stability."""
    indptr: np.ndarray
    indices: np.ndarray
    fanouts: tuple
    seed: int = 0

    def __post_init__(self):
        self.rng = np.random.default_rng(self.seed)
        self.n_nodes = len(self.indptr) - 1

    def max_sample_nodes(self, batch_nodes: int) -> int:
        total, layer = 0, batch_nodes
        for f in (1,) + tuple(self.fanouts):
            layer = layer * f
            total += layer
        return total

    def max_sample_edges(self, batch_nodes: int) -> int:
        total, layer = 0, batch_nodes
        for f in self.fanouts:
            total += layer * f
            layer = layer * f
        return 2 * total  # both directions

    def sample(self, seeds: np.ndarray):
        """Returns (nodes, senders, receivers, masks): local-indexed padded
        subgraph with `seeds` first."""
        batch = len(seeds)
        frontier = seeds.astype(np.int64)
        nodes = [frontier]
        s_loc, r_loc = [], []
        node_pos = {int(v): i for i, v in enumerate(frontier)}
        for f in self.fanouts:
            new = []
            for v in frontier:
                lo, hi = self.indptr[v], self.indptr[v + 1]
                deg = hi - lo
                if deg == 0:
                    continue
                picks = self.indices[
                    lo + self.rng.integers(0, deg, min(f, deg))]
                for u in picks:
                    u = int(u)
                    if u not in node_pos:
                        node_pos[u] = len(node_pos)
                        new.append(u)
                    s_loc.append(node_pos[u])
                    r_loc.append(node_pos[int(v)])
            frontier = np.array(new, np.int64) if new else np.empty(0, np.int64)
            nodes.append(frontier)
        all_nodes = np.concatenate(nodes) if nodes else seeds
        max_n = self.max_sample_nodes(batch)
        max_e = self.max_sample_edges(batch)
        n, e = len(node_pos), len(s_loc)
        node_ids = np.full(max_n, -1, np.int64)
        node_ids[:n] = np.fromiter(node_pos.keys(), np.int64, n)
        senders = np.full(max_e, max_n - 1, np.int32)
        receivers = np.full(max_e, max_n - 1, np.int32)
        senders[:e] = s_loc
        receivers[:e] = r_loc
        # reverse direction for undirected message passing
        senders[e:2 * e] = r_loc
        receivers[e:2 * e] = s_loc
        node_mask = np.zeros(max_n, bool)
        node_mask[:n] = True
        edge_mask = np.zeros(max_e, bool)
        edge_mask[:2 * e] = True
        return node_ids, senders, receivers, node_mask, edge_mask


def sampled_batch(sampler: NeighborSampler, features: np.ndarray,
                  labels: np.ndarray, batch_nodes: int, step: int = 0):
    """One minibatch_lg training batch: sample seeds, gather features."""
    rng = np.random.default_rng(sampler.seed + step)
    seeds = rng.integers(0, sampler.n_nodes, batch_nodes)
    node_ids, snd, rcv, nmask, emask = sampler.sample(seeds)
    safe = np.clip(node_ids, 0, features.shape[0] - 1)
    feat = features[safe] * nmask[:, None]
    lab = np.where(nmask, labels[safe], -1).astype(np.int32)
    # only seed nodes carry supervision
    lab[batch_nodes:] = -1
    return {
        "node_feat": feat.astype(np.float32),
        "senders": snd, "receivers": rcv,
        "node_mask": nmask, "edge_mask": emask,
        "labels": lab, "graph_ids": np.zeros(len(nmask), np.int32),
        "n_graphs": 1,
    }


# --- DPC integration ----------------------------------------------------------


def component_labels(batch):
    """Label the batch's connected components with the paper's algorithm
    (mask = node_mask).  Used by the pipeline for sanity metrics (e.g. the
    number of disconnected fragments a sampler produced)."""
    import jax.numpy as jnp
    from repro.core.connected_components import connected_components_graph
    res = connected_components_graph(
        jnp.asarray(batch["node_mask"]),
        jnp.asarray(batch["senders"]), jnp.asarray(batch["receivers"]))
    return np.asarray(res.labels)
