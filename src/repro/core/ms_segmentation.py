"""Morse-Smale segmentation via path compression (paper §4.2).

The descending manifold maps every vertex to the maximum its steepest-ascent
integral line terminates in; the ascending manifold symmetrically to minima.
Their product partitions the domain into the MS segmentation (the "fast
preview" of the MS complex of Maack et al. [33]).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .pathcompress import path_compress
from .steepest import grid_steepest, graph_steepest


class MSSegmentation(NamedTuple):
    ascending: jax.Array    # flat vertex id of the reached minimum
    descending: jax.Array   # flat vertex id of the reached maximum
    segmentation: jax.Array # injective hash of the (asc, desc) pair
    n_iter_asc: jax.Array
    n_iter_desc: jax.Array


def _fused_init(order, connectivity, fused_impl):
    """Block-local phase through the kernels dispatch (lazy import:
    repro.kernels imports repro.core.steepest at module load).  Returns the
    (possibly pre-saturated) pointer init; the path_compress fixpoint is
    bit-identical to the plain grid_steepest init."""
    from repro.kernels.ops import fused_local_phase
    d0, _ = fused_local_phase(order, connectivity, mode="manifold",
                              impl=fused_impl)
    return d0.ravel()


def descending_manifold(order: jax.Array, connectivity: int = 6,
                        fused_impl: str = "auto"):
    return path_compress(_fused_init(order, connectivity, fused_impl))


def ascending_manifold(order: jax.Array, connectivity: int = 6,
                       fused_impl: str = "auto"):
    # ascending = descending on the flipped order field (the kernel argmax
    # of size-1-order targets exactly grid_steepest's descending=False
    # choice: a monotone transform with unique values preserves the argmax)
    return path_compress(_fused_init(order.size - 1 - order, connectivity,
                                     fused_impl))


def _pair_hash(desc, asc, n):
    """Injective (desc, asc) -> segment id when n*n fits the id dtype; for
    larger grids consume the (ascending, descending) pair directly."""
    dt = jnp.int64 if jax.config.jax_enable_x64 else jnp.int32
    return desc.astype(dt) * n + asc.astype(dt)


def ms_segmentation(order: jax.Array, connectivity: int = 6,
                    fused_impl: str = "auto") -> MSSegmentation:
    desc, it_d = descending_manifold(order, connectivity, fused_impl)
    asc, it_a = ascending_manifold(order, connectivity, fused_impl)
    seg = _pair_hash(desc, asc, order.size)
    return MSSegmentation(asc.reshape(order.shape), desc.reshape(order.shape),
                          seg.reshape(order.shape), it_a, it_d)


def ms_segmentation_graph(order: jax.Array, senders: jax.Array,
                          receivers: jax.Array, connectivity: int = 0
                          ) -> MSSegmentation:
    """Unstructured variant: edges as (senders, receivers) index lists."""
    del connectivity
    d0 = graph_steepest(order, senders, receivers, descending=True)
    desc, it_d = path_compress(d0)
    a0 = graph_steepest(order, senders, receivers, descending=False)
    asc, it_a = path_compress(a0)
    seg = _pair_hash(desc, asc, order.shape[0])
    return MSSegmentation(asc, desc, seg, it_a, it_d)


def extrema(order: jax.Array, connectivity: int = 6):
    """(maxima_mask, minima_mask): vertices that are their own steepest target."""
    n = order.size
    idx = jnp.arange(n, dtype=jnp.int32)
    maxima = grid_steepest(order, connectivity, descending=True) == idx
    minima = grid_steepest(order, connectivity, descending=False) == idx
    return maxima.reshape(order.shape), minima.reshape(order.shape)
