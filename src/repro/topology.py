"""Unified topology query API — the facade over every CC / MS / manifold
entry point (DESIGN.md §Serve).

Callers describe WHAT they want in a `TopologyRequest` (query kind, domain,
backend, payload) instead of choosing among seven near-duplicate functions:

    query    "cc" | "ms" | "manifold" | "threshold_sweep"
    domain   "grid"  (structured, connectivity stencil)
           | "graph" (edge list: both directions of every undirected edge)
    backend  "pure"        (single device)
           | "distributed" (shard_map over a device mesh)

`submit(request)` routes one request to the legacy implementation —
bit-identical to calling it directly (the facade parity contract pinned by
`tests/test_topology_api.py`).  For batched multi-tenant serving with
layout bucketing and compiled-executable caching, hand the same requests to
`repro.serve.TopologyEngine` instead.

Routing table (query, domain, backend) -> legacy entry point:
    cc,  grid,  pure          core.connected_components.connected_components_grid
    cc,  graph, pure          core.connected_components.connected_components_graph
    cc,  grid,  distributed   core.distributed.distributed_connected_components
    cc,  graph, distributed   core.distributed_graph.distributed_connected_components_graph
    ms,  grid,  pure          core.ms_segmentation.ms_segmentation
    ms,  graph, pure          core.ms_segmentation.ms_segmentation_graph
    ms,  grid,  distributed   two core.distributed.distributed_manifold runs + the pair hash
    manifold, grid, pure      core.ms_segmentation.descending/ascending_manifold
    manifold, grid, distributed  core.distributed.distributed_manifold
    threshold_sweep, *, *     vmapped cc over `field > thresholds[k]`

Unsupported combinations raise NotImplementedError naming the gap (e.g.
manifold/ms on distributed graphs needs the order-field halo through
GraphDecomp's ghost layer — the ROADMAP carried item).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .core.connected_components import (connected_components_grid,
                                        connected_components_graph)
from .core.ms_segmentation import (ms_segmentation, ms_segmentation_graph,
                                   descending_manifold, ascending_manifold,
                                   _pair_hash)
from .core.distributed import (distributed_manifold,
                               distributed_connected_components,
                               distributed_connected_components_batch)
from .core.distributed_graph import (
    distributed_connected_components_graph,
    distributed_connected_components_graph_batch)
from .core._table import check_table_mode

QUERIES = ("cc", "ms", "manifold", "threshold_sweep")
DOMAINS = ("grid", "graph")
BACKENDS = ("pure", "distributed")


@dataclasses.dataclass(frozen=True)
class TopologyRequest:
    """One topology query.  Payload fields by query kind:

    cc               mask       (grid: bool array of any extent;
                                 graph: (n,) bool + senders/receivers)
    ms / manifold    order      (int order field — a total vertex order as
                                 produced by `core.compute_order`;
                                 `descending` picks the manifold direction)
    threshold_sweep  field + thresholds (labels CC of `field > t` per t)

    Distributed requests carry `mesh` (grid) or `mesh` + `decomp` (graph).
    `tag` is an opaque caller id, round-tripped onto the result.
    """
    query: str
    domain: str = "grid"
    backend: str = "pure"
    # payloads (query-dependent; unused fields stay None)
    mask: Any = None
    order: Any = None
    field: Any = None
    thresholds: Any = None
    senders: Any = None
    receivers: Any = None
    # knobs
    connectivity: int = 6
    descending: bool = True
    gather_mask: bool = True
    table_mode: str = "replicated"   # boundary/cut table layout: replicated
                                     # all_gather or sharded halo stack
                                     # (deviation (s) in DESIGN.md)
    table_max_iter: int = 64
    # distributed plumbing
    mesh: Any = None
    decomp: Any = None
    tag: Any = None

    def validate(self) -> None:
        if self.query not in QUERIES:
            raise ValueError(f"query {self.query!r} not in {QUERIES}")
        if self.domain not in DOMAINS:
            raise ValueError(f"domain {self.domain!r} not in {DOMAINS}")
        if self.backend not in BACKENDS:
            raise ValueError(f"backend {self.backend!r} not in {BACKENDS}")
        need = {"cc": ("mask",), "ms": ("order",), "manifold": ("order",),
                "threshold_sweep": ("field", "thresholds")}[self.query]
        for f in need:
            if getattr(self, f) is None:
                raise ValueError(f"{self.query} request needs {f}=")
        if self.domain == "graph" and (self.senders is None
                                       or self.receivers is None):
            raise ValueError("graph requests need senders= and receivers=")
        check_table_mode(self.table_mode)
        if self.table_mode != "replicated" and self.backend != "distributed":
            raise ValueError("table_mode='sharded' needs "
                             "backend='distributed' (the pure backends "
                             "have no boundary table)")
        if self.backend == "distributed":
            if self.mesh is None:
                raise ValueError("distributed requests need mesh=")
            if self.domain == "graph" and self.decomp is None:
                raise ValueError("distributed graph requests need decomp= "
                                 "(a core.GraphDecomp)")

    def shape(self):
        """Extent of the request's payload (the bucketing key input)."""
        for f in ("mask", "order", "field"):
            v = getattr(self, f)
            if v is not None:
                return tuple(v.shape)
        raise ValueError("request carries no payload")


@dataclasses.dataclass
class TopologyResult:
    """Facade result.  `labels` carries the query's label array (cc and
    manifold: one array shaped like the input; threshold_sweep: a leading
    (K,) thresholds dim); `ascending`/`descending`/`segmentation` are set
    for ms queries.  `stats` is the backend's DPCStats/GraphDPCStats as a
    uniform dict (distributed only); `meta` holds counters (rounds/iters).
    """
    query: str
    labels: Any = None
    ascending: Any = None
    descending: Any = None
    segmentation: Any = None
    stats: dict | None = None
    meta: dict = dataclasses.field(default_factory=dict)
    tag: Any = None


def _submit_cc(req: TopologyRequest) -> TopologyResult:
    if req.domain == "grid":
        if req.backend == "pure":
            res = connected_components_grid(req.mask, req.connectivity)
            return TopologyResult(
                "cc", labels=res.labels, tag=req.tag,
                meta={"n_rounds": res.n_rounds,
                      "n_compress_iter": res.n_compress_iter})
        labels, st = distributed_connected_components(
            req.mask, req.mesh, req.connectivity, req.gather_mask,
            table_mode=req.table_mode, table_max_iter=req.table_max_iter)
        return TopologyResult("cc", labels=labels, stats=st.as_dict(),
                              tag=req.tag)
    if req.backend == "pure":
        res = connected_components_graph(req.mask, req.senders,
                                         req.receivers)
        return TopologyResult(
            "cc", labels=res.labels, tag=req.tag,
            meta={"n_rounds": res.n_rounds,
                  "n_compress_iter": res.n_compress_iter})
    labels, st = distributed_connected_components_graph(
        req.mask, req.decomp, req.mesh, req.gather_mask,
        table_mode=req.table_mode, table_max_iter=req.table_max_iter)
    return TopologyResult("cc", labels=labels, stats=st.as_dict(),
                          tag=req.tag)


def _submit_manifold(req: TopologyRequest) -> TopologyResult:
    if req.domain == "graph":
        raise NotImplementedError(
            "manifolds on distributed graphs need an order-field halo "
            "through GraphDecomp's ghost layer (ROADMAP carried item); "
            "for single-device graphs use query='ms'")
    if req.backend == "pure":
        fn = descending_manifold if req.descending else ascending_manifold
        labels, it = fn(req.order, req.connectivity)
        return TopologyResult("manifold",
                              labels=labels.reshape(req.order.shape),
                              meta={"n_iter": it}, tag=req.tag)
    labels, st = distributed_manifold(req.order, req.mesh, req.connectivity,
                                      req.descending,
                                      table_mode=req.table_mode,
                                      table_max_iter=req.table_max_iter)
    return TopologyResult("manifold", labels=labels, stats=st.as_dict(),
                          tag=req.tag)


def _submit_ms(req: TopologyRequest) -> TopologyResult:
    if req.domain == "graph":
        if req.backend == "distributed":
            raise NotImplementedError(
                "MS on distributed graphs needs the order-field halo "
                "(ROADMAP carried item)")
        res = ms_segmentation_graph(req.order, req.senders, req.receivers)
        return TopologyResult("ms", ascending=res.ascending,
                              descending=res.descending,
                              segmentation=res.segmentation,
                              meta={"n_iter_asc": res.n_iter_asc,
                                    "n_iter_desc": res.n_iter_desc},
                              tag=req.tag)
    if req.backend == "pure":
        res = ms_segmentation(req.order, req.connectivity)
        return TopologyResult("ms", ascending=res.ascending,
                              descending=res.descending,
                              segmentation=res.segmentation,
                              meta={"n_iter_asc": res.n_iter_asc,
                                    "n_iter_desc": res.n_iter_desc},
                              tag=req.tag)
    # distributed ms = both manifold directions + the (desc, asc) pair hash
    # (each direction bit-identical to the pure manifolds, so the hash is
    # bit-identical to pure ms_segmentation on the same order field)
    desc, st_d = distributed_manifold(req.order, req.mesh, req.connectivity,
                                      descending=True,
                                      table_mode=req.table_mode,
                                      table_max_iter=req.table_max_iter)
    asc, st_a = distributed_manifold(req.order, req.mesh, req.connectivity,
                                     descending=False,
                                     table_mode=req.table_mode,
                                     table_max_iter=req.table_max_iter)
    seg = _pair_hash(desc, asc, req.order.size)
    return TopologyResult("ms", ascending=asc, descending=desc,
                          segmentation=seg,
                          stats={"descending": st_d.as_dict(),
                                 "ascending": st_a.as_dict()},
                          tag=req.tag)


def _sweep_masks(req: TopologyRequest):
    thr = jnp.asarray(req.thresholds).reshape(-1)
    return thr, jnp.asarray(req.field)


def _submit_sweep(req: TopologyRequest) -> TopologyResult:
    """CC of `field > t` for every threshold t, vmapped over one field."""
    thr, field = _sweep_masks(req)
    if req.domain == "grid":
        if req.backend == "pure":
            labels = jax.vmap(
                lambda t: connected_components_grid(
                    field > t, req.connectivity).labels)(thr)
            return TopologyResult("threshold_sweep", labels=labels,
                                  tag=req.tag)
        labels, st = distributed_connected_components_batch(
            field[None] > thr.reshape((-1,) + (1,) * field.ndim),
            req.mesh, req.connectivity, req.gather_mask,
            table_mode=req.table_mode, table_max_iter=req.table_max_iter)
        return TopologyResult("threshold_sweep", labels=labels,
                              stats=st.as_dict(), tag=req.tag)
    if req.backend == "pure":
        labels = jax.vmap(
            lambda t: connected_components_graph(
                field > t, req.senders, req.receivers).labels)(thr)
        return TopologyResult("threshold_sweep", labels=labels, tag=req.tag)
    labels, st = distributed_connected_components_graph_batch(
        field[None] > thr[:, None], req.decomp, req.mesh, req.gather_mask,
        table_mode=req.table_mode, table_max_iter=req.table_max_iter)
    return TopologyResult("threshold_sweep", labels=labels,
                          stats=st.as_dict(), tag=req.tag)


_ROUTES = {"cc": _submit_cc, "ms": _submit_ms, "manifold": _submit_manifold,
           "threshold_sweep": _submit_sweep}


def submit(request: TopologyRequest) -> TopologyResult:
    """Route one request to its legacy implementation (bit-identical)."""
    request.validate()
    return _ROUTES[request.query](request)


def submit_many(requests) -> list:
    """Sequential reference path: one `submit` per request.  The batched
    engine (`repro.serve.TopologyEngine`) must match this bit-for-bit."""
    return [submit(r) for r in requests]


__all__ = ["TopologyRequest", "TopologyResult", "submit", "submit_many",
           "QUERIES", "DOMAINS", "BACKENDS"]
