"""Shared LM-family shape set (assigned per-arch inline in the task)."""

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    # decode (1 token vs a 524288-entry KV cache) is O(L) per token, so it
    # runs for full-attention archs too — see DESIGN.md §4 long_500k note.
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# smoke shapes stay divisible by the production meshes (dp<=32, sp=16,
# ep_all<=512) so `dryrun --smoke` exercises the identical sharding paths
SMOKE_SHAPES = {
    "train_4k": {"kind": "train", "seq": 256, "batch": 64},
    "prefill_32k": {"kind": "prefill", "seq": 256, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 512, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 1024, "batch": 1},
}
