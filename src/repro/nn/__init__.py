from .core import (dense_init, embed_init, rms_norm, rope, swiglu,
                   cross_entropy_chunked, Param)
