"""Pallas TPU kernel: in-VMEM block path compression.

TPU adaptation of the paper's thread-local compression: right after the
steepest init every pointer targets a direct neighbor, so the first K
doubling rounds stay almost entirely inside an x-slab.  Running those rounds
on a VMEM-resident tile costs one HBM read + one write for K rounds, versus
K full HBM round-trips for global `d <- d[d]` gathers (each of which moves
8 bytes/vertex/round at 819 GB/s).  Out-of-block and negative pointers are
fixed points, exactly like ghost vertices in Alg. 1 — the block boundary IS
a ghost boundary, so correctness follows from the same argument as the
distributed algorithm, and the remaining global rounds finish the job.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(d_ref, out_ref, *, rounds, block):
    i = pl.program_id(0)
    base = i * block
    d = d_ref[...]
    for _ in range(rounds):
        local = d - base
        in_block = (d >= 0) & (local >= 0) & (local < block)
        nd = jnp.take(d, jnp.clip(local, 0, block - 1), axis=0)
        d = jnp.where(in_block, nd, d)
    out_ref[...] = d


@functools.partial(jax.jit,
                   static_argnames=("rounds", "block", "interpret"))
def block_pathcompress(d: jax.Array, rounds: int = 4, block: int = 4096,
                       interpret: bool = True) -> jax.Array:
    """K pointer-doubling rounds confined to `block`-sized tiles.

    d: (N,) int32 global pointers (N divisible by block, or block clamped).
    """
    n = d.shape[0]
    if n % block:
        block = n
    kernel = functools.partial(_kernel, rounds=rounds, block=block)
    return pl.pallas_call(
        kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), d.dtype),
        interpret=interpret,
    )(d)
