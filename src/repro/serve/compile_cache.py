"""Cross-engine shared executable cache (DESIGN.md §Serve-v3).

PR 8 gave each engine its own bounded-LRU executable cache, so N engine
replicas serving the same tenant mix paid N identical compiles for every
(kind, backend, layout, capacity, dtype, table_mode) executable — compile
time is the dominant cold-start cost of the plane.  `SharedExecutableCache`
factors that cache out: any number of `TopologyEngine` /
`AsyncTopologyEngine` instances (sync and async alike) attach to one cache
and each executable compiles exactly once, whichever engine asks first.

Attribution stays per engine: `attach()` hands out an owner tag and
`lookup()` charges the hit or miss to it, so per-replica hit rates remain
observable (`attribution()`) even though the store is shared.

Invalidation rules (deliberately minimal):
  * LRU only — an insert past `capacity` evicts the least-recently-used
    entry, whichever engine inserted it; `capacity=None` disables eviction.
  * Executables are keyed by everything that shapes the compiled program
    (the engine's `_exec_key`), so entries never go stale — there is no
    TTL and no explicit invalidation API.
  * The plane is cooperative single-threaded on an injected clock
    (DESIGN.md §Serve-v2), so the cache takes no locks; callers running
    engines from multiple threads must serialize externally.
"""
from __future__ import annotations

import collections
import itertools
from typing import Any, Callable


class SharedExecutableCache:
    """Bounded LRU of compiled executables, shareable across engines."""

    def __init__(self, capacity: int | None = 64):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be >= 1 or None, got {capacity}")
        self.capacity = None if capacity is None else int(capacity)
        self.compiles = 0     # build() invocations == distinct cold compiles
        self.evictions = 0
        self._store: collections.OrderedDict = collections.OrderedDict()
        self._owners: dict = {}       # owner tag -> {"hits": n, "misses": n}
        self._ids = itertools.count()

    # --- attachment -----------------------------------------------------------

    def attach(self, name: str | None = None) -> str:
        """Register an engine and return its owner tag (auto-numbered when
        `name` is None; attaching an existing name rejoins its counters)."""
        owner = f"engine-{next(self._ids)}" if name is None else str(name)
        self._owners.setdefault(owner, {"hits": 0, "misses": 0})
        return owner

    # --- the one hot-path operation -------------------------------------------

    def lookup(self, key, build: Callable[[], Any], owner: str):
        """Return `(executable, hit, evicted)`; on a miss, compile via
        `build()` and insert.  The hit/miss is charged to `owner`;
        `evicted` is how many entries the insert pushed out (0 or 1)."""
        counters = self._owners.setdefault(owner, {"hits": 0, "misses": 0})
        cached = self._store.get(key)
        if cached is not None:
            counters["hits"] += 1
            self._store.move_to_end(key)
            return cached, True, 0
        counters["misses"] += 1
        self.compiles += 1
        built = build()
        self._store[key] = built
        evicted = 0
        if self.capacity is not None and len(self._store) > self.capacity:
            self._store.popitem(last=False)
            self.evictions += 1
            evicted = 1
        return built, False, evicted

    # --- introspection --------------------------------------------------------

    def __len__(self) -> int:
        return len(self._store)

    def __contains__(self, key) -> bool:
        return key in self._store

    def attribution(self) -> dict:
        """Per-attached-engine hit/miss counters."""
        return {owner: dict(c) for owner, c in self._owners.items()}

    def info(self) -> dict:
        return {
            "size": len(self._store),
            "capacity": self.capacity,
            "compiles": self.compiles,
            "evictions": self.evictions,
            "engines": self.attribution(),
        }


__all__ = ["SharedExecutableCache"]
