"""Pure-jnp oracles for every Pallas kernel (the allclose references)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# --- steepest_neighbor ------------------------------------------------------


def steepest_neighbor_ref(order: jax.Array, offsets, id_offset: int = 0):
    """Reference for the 3D steepest-neighbor stencil: for every voxel the
    global flat id of the argmax-order vertex among itself and `offsets`.
    order: (X, Y, Z) int32; returns (X, Y, Z) int32 of flat ids + id_offset.
    """
    from repro.core.steepest import shift_fill
    n = order.size
    idx = (jnp.arange(n, dtype=jnp.int32) + id_offset).reshape(order.shape)
    best_val, best_idx = order, idx
    fill = jnp.iinfo(order.dtype).min
    for off in offsets:
        cv = shift_fill(order, off, fill)
        ci = shift_fill(idx, off, -1)
        better = cv > best_val
        best_val = jnp.where(better, cv, best_val)
        best_idx = jnp.where(better, ci, best_idx)
    return best_idx


# --- block_pathcompress -----------------------------------------------------


def block_pathcompress_ref(d: jax.Array, rounds: int, base: int = 0):
    """`rounds` pointer-doubling steps where gathers are confined to the
    block: out-of-block or negative pointers are fixed points."""
    n = d.shape[0]
    for _ in range(rounds):
        local = d - base
        in_block = (d >= 0) & (local >= 0) & (local < n)
        nd = d[jnp.clip(local, 0, n - 1)]
        d = jnp.where(in_block, nd, d)
    return d


# --- fused_local_phase -------------------------------------------------------


def fused_local_phase_ref(field, connectivity: int, mode: str = "manifold",
                          self_mask=None, block_x: int = 8, id_dtype=None):
    """Bit-exact host-side oracle for the fused block-local kernel: pointer
    init (steepest argmax / largest masked neighbor id) with the optional
    self-mask override, then per-x-slab pointer doubling to the slab-local
    fixpoint, counting rounds exactly like the kernel's while loop (the
    final no-change verification round included).  Returns
    ((X, Y, Z) pointers, max rounds over slabs)."""
    from repro.core.steepest import grid_steepest, grid_mask_argmax
    field = np.asarray(field)
    x = field.shape[0]
    R = int(np.prod(field.shape[1:]))
    n = field.size
    if id_dtype is None:
        id_dtype = jnp.int32 if n < 2**31 else jnp.int64
    np_dt = np.dtype(id_dtype)
    if mode == "manifold":
        d = np.asarray(grid_steepest(jnp.asarray(field), connectivity))
    else:
        d = np.asarray(grid_mask_argmax(jnp.asarray(field), connectivity))
    d = d.astype(np_dt)
    if self_mask is not None:
        keep = np.asarray(self_mask, bool).ravel()
        if mode == "cc":
            keep = keep & (field.ravel() != 0)
        d = np.where(keep, np.arange(n, dtype=np_dt), d)

    tsize = block_x * R
    max_rounds = max((tsize - 1).bit_length(), 1) + 1
    n_tiles = -(-x // block_x)
    rounds_max = 0
    for t in range(n_tiles):
        lo = t * block_x * R
        hi = min((t + 1) * block_x, x) * R
        seg = d[lo:hi]
        r, changed = 0, True
        while changed and r < max_rounds:
            local = seg - lo
            in_tile = (seg >= 0) & (local >= 0) & (local < hi - lo)
            nxt = np.where(in_tile, seg[np.clip(local, 0, hi - lo - 1)], seg)
            changed = bool((nxt != seg).any())
            seg, r = nxt, r + 1
        d[lo:hi] = seg
        rounds_max = max(rounds_max, r)
    return (jnp.asarray(d.reshape(field.shape)),
            jnp.int32(rounds_max))


# --- flash attention ---------------------------------------------------------


def mha_ref(q, k, v, causal: bool = False, scale: float | None = None):
    """Unfused reference attention.  q: (B, H, Sq, D), k/v: (B, Hkv, Skv, D).
    GQA: H a multiple of Hkv."""
    b, h, sq, d = q.shape
    hkv = k.shape[1]
    group = h // hkv
    scale = scale or (1.0 / np.sqrt(d))
    kk = jnp.repeat(k, group, axis=1)
    vv = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        kk.astype(jnp.float32)) * scale
    if causal:
        skv = k.shape[2]
        mask = jnp.arange(sq)[:, None] + (skv - sq) >= jnp.arange(skv)[None, :]
        logits = jnp.where(mask, logits, -jnp.inf)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, vv.astype(jnp.float32)).astype(q.dtype)


def flash_attention_ref(q, k, v, causal: bool = False, block_kv: int = 128,
                        scale: float | None = None):
    """Chunked (online-softmax) attention in pure jnp — numerically the
    flash schedule, used both as the kernel oracle and as the model-side
    attention implementation for dry-runs (no S x S buffer)."""
    b, h, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = h // hkv
    scale = scale or (1.0 / np.sqrt(d))
    qf = q.astype(jnp.float32) * scale
    nblk = max(skv // block_kv, 1)
    blk = skv // nblk

    def body(carry, i):
        m, l, acc = carry
        ks = lax.dynamic_slice_in_dim(k, i * blk, blk, axis=2)
        vs = lax.dynamic_slice_in_dim(v, i * blk, blk, axis=2)
        ks = jnp.repeat(ks, group, axis=1).astype(jnp.float32)
        vs = jnp.repeat(vs, group, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, ks)
        if causal:
            qpos = jnp.arange(sq)[:, None] + (skv - sq)
            kpos = i * blk + jnp.arange(blk)[None, :]
            s = jnp.where(qpos >= kpos, s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vs)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    a0 = jnp.zeros((b, h, sq, d), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), jnp.arange(nblk))
    return (acc / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)
