"""Fused block-local phase inside the distributed hot path: labels stay
bit-identical to the pure-oracle paths on ragged corpus cases, for the
single-request AND the batched (vmap-inside-shard_map) entry points, while
`DPCStats.kernel_rounds` certifies the global doubling rounds saved.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps its single-device view.
"""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    sys.path.insert(0, os.path.join(%(root)r, "tests"))
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components,
                            distributed_manifold_batch,
                            distributed_connected_components_batch)
    from oracles import ragged_grid_case

    assert len(jax.devices()) == 8
    failures = []

    def corpus_3d(max_cases):
        out, seed = [], 0
        while len(out) < max_cases and seed < 64:
            shape, layout, conn, mask_p = ragged_grid_case(seed)
            if len(shape) == 3:
                out.append((seed, shape, layout, conn, mask_p))
            seed += 1
        return out

    for seed, shape, layout, conn, mask_p in corpus_3d(2):
        rng = np.random.default_rng(seed)
        mesh = make_dpc_mesh(layout)
        order = jnp.asarray(rng.permutation(int(np.prod(shape)))
                            .reshape(shape).astype(np.int32))
        mask = jnp.asarray(rng.random(shape) < mask_p)

        l0, s0 = distributed_manifold(order, mesh, conn, fused_impl="ref")
        l1, s1 = distributed_manifold(order, mesh, conn, fused_impl="kernel")
        if not (np.asarray(l0) == np.asarray(l1)).all():
            failures.append(("manifold", seed))
        # the kernel certifies the saturation depth; the jnp path reports 0
        if not (int(s1.kernel_rounds) >= 1 and int(s0.kernel_rounds) == 0):
            failures.append(("manifold-rounds", seed))
        # fused local loop never needs MORE rounds than the unfused one
        if int(s1.local_iters) > int(s0.local_iters):
            failures.append(("manifold-iters", seed))
        d = s1.as_dict()
        if not (d["global_iters_saved"]
                == max(d["kernel_rounds"] - d["local_iters"], 0)):
            failures.append(("manifold-saved", seed))

        c0, t0 = distributed_connected_components(mask, mesh, conn,
                                                  fused_impl="ref")
        c1, t1 = distributed_connected_components(mask, mesh, conn,
                                                  fused_impl="kernel")
        if not (np.asarray(c0) == np.asarray(c1)).all():
            failures.append(("cc", seed))
        if not int(t1.kernel_rounds) >= 1:
            failures.append(("cc-rounds", seed))

    # batched: one ragged 3-D case, per-item bit-identity vs single-request
    seed, shape, layout, conn, mask_p = corpus_3d(1)[0]
    rng = np.random.default_rng(100 + seed)
    mesh = make_dpc_mesh(layout)
    B = 3
    orders = jnp.stack([jnp.asarray(rng.permutation(int(np.prod(shape)))
                                    .reshape(shape).astype(np.int32))
                        for _ in range(B)])
    masks = jnp.stack([jnp.asarray(rng.random(shape) < mask_p)
                       for _ in range(B)])
    bl, bs = distributed_manifold_batch(orders, mesh, conn,
                                        fused_impl="kernel")
    bc, bt = distributed_connected_components_batch(masks, mesh, conn,
                                                    fused_impl="kernel")
    for i in range(B):
        li, _ = distributed_manifold(orders[i], mesh, conn,
                                     fused_impl="kernel")
        if not (np.asarray(bl[i]) == np.asarray(li)).all():
            failures.append(("batch-manifold", i))
        ci, _ = distributed_connected_components(masks[i], mesh, conn,
                                                 fused_impl="kernel")
        if not (np.asarray(bc[i]) == np.asarray(ci)).all():
            failures.append(("batch-cc", i))
    if not all(r >= 1 for r in np.asarray(bs.kernel_rounds).tolist()):
        failures.append(("batch-rounds", -1))

    assert not failures, failures
    print("FUSED-DIST-OK")
""") % {"root": _ROOT}


def test_fused_distributed_matches_oracles():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "FUSED-DIST-OK" in proc.stdout
