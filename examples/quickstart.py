"""Quickstart: the paper in 60 seconds.

Computes the Morse-Smale segmentation and thresholded connected components
of a 3D Perlin-noise field (the paper's dataset), first on one device, then
distributed over every local device with DPC (Alg. 1+2) — and checks they
agree.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import compute_order, compact_labels, make_dpc_mesh
from repro.core.connected_components import connected_components_grid
from repro.core.ms_segmentation import ms_segmentation
from repro.core.distributed import (distributed_manifold,
                                    distributed_connected_components)
from repro.data import perlin_noise


def main():
    # --- the scalar field (paper §5: Perlin noise, frequency 0.1) ---------
    # cubic, so the block lattice's surface-to-volume edge over slabs shows
    shape = (32, 32, 32)
    field = perlin_noise(shape, frequency=0.1, seed=42)
    order = compute_order(jnp.asarray(field))   # Simulation-of-Simplicity

    # --- Morse-Smale segmentation (paper Alg. 1) ---------------------------
    seg = ms_segmentation(order, connectivity=6)
    _, n_segments = compact_labels(seg.segmentation)
    n_max = len(np.unique(np.asarray(seg.descending)))
    n_min = len(np.unique(np.asarray(seg.ascending)))
    print(f"MS segmentation of {shape}: {n_segments} segments "
          f"({n_max} maxima x {n_min} minima), "
          f"{int(seg.n_iter_desc)} doubling rounds")

    # --- connected components of the top-10% mask (paper Alg. 3) ----------
    mask = jnp.asarray(field > np.quantile(field, 0.9))
    cc = connected_components_grid(mask, connectivity=6)
    labels = np.asarray(cc.labels)
    n_comp = len(np.unique(labels[labels >= 0]))
    print(f"top-10% mask: {int(mask.sum())} vertices in {n_comp} components "
          f"({int(cc.n_rounds)} stitch rounds, {int(cc.n_compress_iter)} "
          f"compress iters)")

    # --- distributed (DPC) over all local devices --------------------------
    n_dev = len(jax.devices())
    n_shards = max(d for d in range(1, n_dev + 1) if shape[0] % d == 0)
    mesh = make_dpc_mesh(n_shards)
    dseg, stats = distributed_manifold(order, mesh, 6, descending=True)
    assert (np.asarray(dseg).ravel()
            == np.asarray(seg.descending).ravel()).all()
    dcc, cstats = distributed_connected_components(mask, mesh, 6)
    assert (np.asarray(dcc) == labels).all()
    print(f"DPC on {n_shards} slab(s): identical labels; one exchange of "
          f"{int(stats.ghost_bytes):,} ghost bytes, "
          f"{int(stats.table_iters)} table rounds "
          f"(CC masked ghost fraction {float(cstats.masked_ghost_fraction):.3f})")

    # --- same, on an N-D block lattice (better surface-to-volume) ----------
    layout = {8: (2, 2, 2), 4: (2, 2), 2: (2,)}.get(n_dev)
    if layout and all(s % p == 0 for s, p in zip(shape, layout)):
        bmesh = make_dpc_mesh(layout)
        bseg, bstats = distributed_manifold(order, bmesh, 6, descending=True)
        assert (np.asarray(bseg).ravel()
                == np.asarray(seg.descending).ravel()).all()
        bcc, _ = distributed_connected_components(mask, bmesh, 6)
        assert (np.asarray(bcc) == labels).all()
        tag = "x".join(map(str, layout))
        print(f"DPC on {tag} blocks: identical labels; one exchange of "
              f"{int(bstats.ghost_bytes):,} ghost bytes "
              f"(vs {int(stats.ghost_bytes):,} for slabs)")

    # --- ragged extents: nothing needs to divide the mesh ------------------
    # (pad-and-mask, deviation (p) in DESIGN.md — the paper's real dataset
    # shapes are never multiples of the node count)
    rshape = tuple(s - 1 for s in shape)     # crop to a non-divisible size
    rorder = compute_order(jnp.asarray(np.asarray(field)[
        tuple(slice(0, s) for s in rshape)]))
    rmesh = make_dpc_mesh(n_dev)
    rseg, rstats = distributed_manifold(rorder, rmesh, 6, descending=True)
    rref = ms_segmentation(rorder, connectivity=6)
    assert (np.asarray(rseg).ravel()
            == np.asarray(rref.descending).ravel()).all()
    print(f"DPC on a ragged {'x'.join(map(str, rshape))} grid over "
          f"{n_dev} device(s): identical labels, pad fraction "
          f"{float(rstats.pad_fraction):.3f}, still "
          f"{int(rstats.comm_phases)} exchange phase")


if __name__ == "__main__":
    main()
