"""Sharded boundary table (deviation (s), DESIGN.md §Table-sharding).

* bit-parity: `table_mode="sharded"` must produce labels bit-identical to
  the replicated table AND the single-device references — grid manifold,
  grid CC, graph CC, single and batched, gather_mask on/off;
* the memory win the mode exists for: per-device `table_bytes_peak` of the
  sharded manifold table shrinks relative to replicated as the block
  lattice grows, and is STRICTLY smaller at (2, 2, 2);
* round accounting: replicated keeps the paper's one-phase budget
  (comm_phases == 1, exchange_rounds == 0); sharded reports its outer
  exchange rounds and comm_phases consistently;
* convergence surface: `converged` is 1 on every normal run, and a tiny
  `table_max_iter` raises RuntimeError eagerly instead of returning
  mid-chain labels;
* the boundary-coords build cache: repeated same-geometry calls must not
  rebuild (or re-upload) the coordinate table — the recompile-regression
  counterpart of the `_padded_call` cache test in test_kernels.py.

Device-count-dependent checks run in subprocesses under
XLA_FLAGS=--xla_force_host_platform_device_count=8 (dry-run rule: never
set the flag globally).
"""
import os
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_worker(worker: str, sentinel: str, timeout: int = 900):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", worker], env=env,
                          capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert sentinel in proc.stdout


# --- in-process: knob validation and the converged flags ---------------------

def test_table_mode_validation():
    from repro.core._table import TABLE_MODES, check_table_mode
    assert TABLE_MODES == ("replicated", "sharded")
    check_table_mode("replicated")
    check_table_mode("sharded")
    with pytest.raises(ValueError, match="table_mode"):
        check_table_mode("bogus")


def test_topology_request_rejects_sharded_on_pure_backend():
    from repro.topology import TopologyRequest
    req = TopologyRequest("cc", mask=jnp.ones((4, 4), bool), connectivity=4,
                          table_mode="sharded")
    with pytest.raises(ValueError, match="backend='distributed'"):
        req.validate()
    with pytest.raises(ValueError, match="table_mode"):
        TopologyRequest("cc", mask=jnp.ones((4, 4), bool), connectivity=4,
                        table_mode="bogus").validate()


def test_pointer_chase_reports_convergence():
    from repro.core._table import pointer_chase
    base = jnp.array([1, 2, 3, 4, 5, 6, 7, 7], jnp.int32)
    t, iters, ok = pointer_chase(base, lambda t: base[t], max_iter=64)
    assert bool(ok) and (t == 7).all() and int(iters) >= 3
    _, _, ok = pointer_chase(base, lambda t: base[t], max_iter=1)
    assert not bool(ok)  # chain of length 7 cannot resolve in one doubling


def test_hook_propagate_reports_convergence():
    from repro.core._table import hook_propagate
    lab = jnp.arange(5, dtype=jnp.int32)

    def cut_max(L):  # chain i <-> i+1: the max walks back one hop per round
        return jnp.maximum(L, jnp.concatenate([L[1:], L[-1:]]))

    out, iters, ok = hook_propagate(lab, cut_max, lambda L: L, max_iter=64)
    assert bool(ok) and (out == 4).all()
    _, _, ok = hook_propagate(lab, cut_max, lambda L: L, max_iter=1)
    assert not bool(ok)


def test_check_converged_raises_outside_jit():
    import numpy as np
    from repro.core._table import check_converged
    check_converged(np.asarray(True), "unit", 64)          # no-op when ok
    with pytest.raises(RuntimeError, match="table_max_iter"):
        check_converged(np.asarray(False), "unit", 2)


# --- in-process: boundary-coords build cache (recompile regression) ----------

def test_boundary_coords_built_once_per_decomp():
    from repro.core import distributed as D
    D._decomp_cached.cache_clear()
    before = D.BlockDecomp._coords_builds
    dec = D._decomp_cached((8, 8, 8), (2, 2), ("a", "b"))
    c1 = dec.boundary_coords
    c2 = dec.boundary_coords                 # cached_property: same object
    assert c1 is c2
    d1 = dec.boundary_coords_dev
    d2 = dec.boundary_coords_dev             # device upload cached too
    assert d1 is d2
    assert D.BlockDecomp._coords_builds == before + 1

    # same geometry -> same BlockDecomp -> no rebuild
    dec2 = D._decomp_cached((8, 8, 8), (2, 2), ("a", "b"))
    assert dec2 is dec
    _ = dec2.boundary_coords
    assert D.BlockDecomp._coords_builds == before + 1

    # new geometry -> exactly one more build
    dec3 = D._decomp_cached((8, 8, 6), (2, 2), ("a", "b"))
    _ = dec3.boundary_coords
    assert D.BlockDecomp._coords_builds == before + 2


# --- subprocess: parity + memory + accounting on 8 fake devices --------------

# One distributed (2,2,2) program costs ~30s of XLA compile on the CPU CI
# runner, so the fast smoke compiles the minimum that pins the acceptance
# claims: one ragged parity case (both kinds, both modes, vs the numpy
# oracles), the memory-ratio sweep, and the tiny-max_iter refusal.  The
# connectivity sweep (14/18/26), more layouts, batching, x64 and the full
# seed corpus run in the slow (nightly) workers below.
_GRID_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {tests_dir!r})
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components, compute_order)
    from oracles import oracle_manifold, oracle_components

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)

    grid, conn = (7, 6, 5), 6                 # ragged under (2, 2, 2)
    order = compute_order(jnp.asarray(rng.standard_normal(grid)))
    mask = jnp.asarray(rng.random(grid) < 0.5)
    mesh = make_dpc_mesh((2, 2, 2))

    lr, sr = distributed_manifold(order, mesh, conn)
    ls, ss = distributed_manifold(order, mesh, conn, table_mode="sharded")
    ref = oracle_manifold(np.asarray(order), conn)
    assert (np.asarray(lr).ravel() == ref.ravel()).all(), "manifold-ref"
    assert (np.asarray(lr) == np.asarray(ls)).all(), "manifold parity"
    # replicated keeps the paper's budget; sharded reports its rounds
    assert int(sr.comm_phases) == 1 and int(sr.exchange_rounds) == 0
    assert int(ss.exchange_rounds) >= 1
    assert int(ss.comm_phases) == int(ss.exchange_rounds)
    assert int(sr.converged) == 1 and int(ss.converged) == 1

    lrc, src = distributed_connected_components(mask, mesh, conn)
    lsc, ssc = distributed_connected_components(mask, mesh, conn,
                                                table_mode="sharded")
    refc = oracle_components(np.asarray(mask), conn)
    assert (np.asarray(lrc) == refc).all(), "cc-ref"
    assert (np.asarray(lrc) == np.asarray(lsc)).all(), "cc parity"
    assert int(src.comm_phases) == 1 and int(src.exchange_rounds) == 0
    # CC ships the static masked table once, then exchanges labels
    assert int(ssc.comm_phases) == int(ssc.exchange_rounds) + 1
    assert int(src.converged) == 1 and int(ssc.converged) == 1
    # the masked-ghost surface metric must not depend on table layout
    assert abs(float(src.masked_ghost_fraction)
               - float(ssc.masked_ghost_fraction)) < 1e-6

    # THE memory claim: per-device manifold table bytes, sharded vs
    # replicated, on one grid across a growing block lattice.  Replication
    # pays the whole table on every device; the sharded stack only pays
    # own rows + the one-hop halo, so the ratio falls as the lattice grows
    # and drops strictly below 1 at (2, 2, 2).
    ratios = [int(ss.table_bytes_peak) / int(sr.table_bytes_peak)]
    for layout in [(2, 2), (2,)]:
        _, st_r = distributed_manifold(order, make_dpc_mesh(layout), conn)
        _, st_s = distributed_manifold(order, make_dpc_mesh(layout), conn,
                                       table_mode="sharded")
        ratios.insert(0,
                      int(st_s.table_bytes_peak) / int(st_r.table_bytes_peak))
    assert ratios[0] > ratios[1] > ratios[2], ratios
    assert ratios[2] < 1.0, ratios           # strict win at (2, 2, 2)

    # tiny max_iter: refuse loudly, never return mid-chain labels
    try:
        distributed_manifold(order, mesh, conn, table_mode="sharded",
                             table_max_iter=1)
        raise SystemExit("tiny table_max_iter did not raise")
    except RuntimeError as e:
        assert "table_max_iter" in str(e)

    # ... and symmetrically on the REPLICATED table (serve-v3 bugfix
    # sweep): same refusal, cheapest layout to keep the compile small
    try:
        distributed_manifold(order, make_dpc_mesh((2,)), conn,
                             table_max_iter=1)
        raise SystemExit("replicated tiny table_max_iter did not raise")
    except RuntimeError as e:
        assert "table_max_iter" in str(e)

    print("SHARDED-GRID-OK")
""").format(tests_dir=os.path.dirname(os.path.abspath(__file__)))


def test_sharded_grid_parity_and_memory():
    _run_worker(_GRID_WORKER, "SHARDED-GRID-OK")


_GRAPH_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import make_dpc_mesh
    from repro.core.connected_components import connected_components_graph
    from repro.core.distributed_graph import (
        GraphDecomp, distributed_connected_components_graph)

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(3)
    n, m = 61, 120
    a = rng.integers(0, n, m); b = rng.integers(0, n, m)
    keep = a != b
    a, b = a[keep], b[keep]
    s, r = np.concatenate([a, b]), np.concatenate([b, a])
    dec = GraphDecomp(n, s, r, 8)
    mesh = make_dpc_mesh(8)
    mask = jnp.asarray(rng.random(n) < 0.6)
    ref = connected_components_graph(mask, jnp.asarray(s), jnp.asarray(r))

    lr, sr = distributed_connected_components_graph(mask, dec, mesh)
    ls, ss = distributed_connected_components_graph(mask, dec, mesh,
                                                    table_mode="sharded")
    assert (np.asarray(lr) == np.asarray(ref.labels)).all(), "graph-ref"
    assert (np.asarray(lr) == np.asarray(ls)).all(), "graph parity"
    assert int(sr.comm_phases) == 1 and int(sr.exchange_rounds) == 0
    assert int(ss.comm_phases) == int(ss.exchange_rounds) + 1
    assert int(sr.converged) == 1 and int(ss.converged) == 1
    assert abs(float(sr.masked_ghost_fraction)
               - float(ss.masked_ghost_fraction)) < 1e-6

    try:
        distributed_connected_components_graph(
            mask, dec, mesh, table_mode="sharded", table_max_iter=1)
        raise SystemExit("tiny table_max_iter did not raise")
    except RuntimeError as e:
        assert "table_max_iter" in str(e)

    # symmetric replicated refusal (serve-v3 bugfix sweep)
    try:
        distributed_connected_components_graph(
            mask, dec, mesh, table_max_iter=1)
        raise SystemExit("replicated tiny table_max_iter did not raise")
    except RuntimeError as e:
        assert "table_max_iter" in str(e)

    print("SHARDED-GRAPH-OK")
""")


def test_sharded_graph_parity():
    _run_worker(_GRAPH_WORKER, "SHARDED-GRAPH-OK")


# --- slow: connectivity sweep, more layouts, batched, gather_mask=False ------

_SWEEP_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {tests_dir!r})
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components, compute_order)
    from repro.core.distributed import (distributed_manifold_batch,
                                        distributed_connected_components_batch)
    from repro.core.distributed_graph import (
        GraphDecomp, distributed_connected_components_graph,
        distributed_connected_components_graph_batch)
    from oracles import (oracle_manifold, oracle_components,
                         oracle_components_graph)

    assert len(jax.devices()) == 8
    rng = np.random.default_rng(0)

    # every supported 3-D connectivity (incl. the Moore-halo ones) plus
    # slab / 2-D lattices and a 1-D chain — all ragged
    for layout, grid, conn in [((2, 4), (9, 13), 6),
                               ((8,), (23,), 2),
                               ((2, 2, 2), (5, 6, 7), 14),
                               ((2, 2, 2), (6, 7, 5), 18),
                               ((2, 2, 2), (5, 6, 7), 26)]:
        mesh = make_dpc_mesh(layout)
        order = compute_order(jnp.asarray(rng.standard_normal(grid)))
        mask = jnp.asarray(rng.random(grid) < 0.5)

        lr, _ = distributed_manifold(order, mesh, conn)
        ls, ss = distributed_manifold(order, mesh, conn,
                                      table_mode="sharded")
        ref = oracle_manifold(np.asarray(order), conn)
        assert (np.asarray(lr).ravel() == ref.ravel()).all(), \\
            ("manifold-ref", layout, grid, conn)
        assert (np.asarray(lr) == np.asarray(ls)).all(), \\
            ("manifold", layout, grid, conn)
        assert int(ss.converged) == 1

        lrc, _ = distributed_connected_components(mask, mesh, conn)
        lsc, sc = distributed_connected_components(mask, mesh, conn,
                                                   table_mode="sharded")
        refc = oracle_components(np.asarray(mask), conn)
        assert (np.asarray(lrc) == refc).all(), \\
            ("cc-ref", layout, grid, conn)
        assert (np.asarray(lrc) == np.asarray(lsc)).all(), \\
            ("cc", layout, grid, conn)
        assert int(sc.converged) == 1

    # batched entry points: vmapped while_loops keep per-item rounds
    grid = (7, 6, 5)
    mesh = make_dpc_mesh((2, 2, 2))
    orders = jnp.stack([compute_order(jnp.asarray(rng.standard_normal(grid)))
                        for _ in range(3)])
    masks = jnp.stack([jnp.asarray(rng.random(grid) < 0.5)
                       for _ in range(3)])
    br, _ = distributed_manifold_batch(orders, mesh, 6)
    bs, bst = distributed_manifold_batch(orders, mesh, 6,
                                         table_mode="sharded")
    assert (np.asarray(br) == np.asarray(bs)).all(), "batched manifold"
    assert np.asarray(bst.converged).all()
    cr, _ = distributed_connected_components_batch(masks, mesh, 6)
    cs, _ = distributed_connected_components_batch(masks, mesh, 6,
                                                   table_mode="sharded")
    assert (np.asarray(cr) == np.asarray(cs)).all(), "batched cc"

    # graph: smaller partition counts, gather_mask=False, batched
    def random_graph(n, m):
        a = rng.integers(0, n, m); b = rng.integers(0, n, m)
        keep = a != b
        a, b = a[keep], b[keep]
        return np.concatenate([a, b]), np.concatenate([b, a])

    for nparts, n, m in [(4, 40, 70), (2, 10, 8)]:
        s, r = random_graph(n, m)
        dec = GraphDecomp(n, s, r, nparts)
        mesh = make_dpc_mesh(nparts)
        mask = jnp.asarray(rng.random(n) < 0.6)
        ref = oracle_components_graph(np.asarray(mask), s, r)
        for gm in (True, False):
            lr, _ = distributed_connected_components_graph(
                mask, dec, mesh, gather_mask=gm)
            ls, ss = distributed_connected_components_graph(
                mask, dec, mesh, gather_mask=gm, table_mode="sharded")
            assert (np.asarray(lr) == ref).all(), ("graph-ref", nparts, gm)
            assert (np.asarray(lr) == np.asarray(ls)).all(), \\
                ("graph", nparts, gm)
            assert int(ss.converged) == 1

    s, r = random_graph(50, 90)
    dec = GraphDecomp(50, s, r, 8)
    mesh = make_dpc_mesh(8)
    masks = jnp.asarray(rng.random((3, 50)) < 0.6)
    glr, _ = distributed_connected_components_graph_batch(masks, dec, mesh)
    gls, _ = distributed_connected_components_graph_batch(
        masks, dec, mesh, table_mode="sharded")
    assert (np.asarray(glr) == np.asarray(gls)).all(), "batched graph"

    print("SHARDED-SWEEP-OK")
""").format(tests_dir=os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_connectivity_and_batch_sweep():
    _run_worker(_SWEEP_WORKER, "SHARDED-SWEEP-OK", timeout=1800)


# --- slow: int64 ids under x64 -----------------------------------------------

_X64_WORKER = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "1"
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components, compute_order)

    assert jax.config.jax_enable_x64
    rng = np.random.default_rng(9)
    grid = (7, 6, 5)
    mesh = make_dpc_mesh((2, 2, 2))
    order = compute_order(jnp.asarray(rng.standard_normal(grid)))
    order = order.astype(jnp.int64)
    lr, sr = distributed_manifold(order, mesh, 6)
    ls, ss = distributed_manifold(order, mesh, 6, table_mode="sharded")
    # id dtype follows the DECOMPOSITION size (int32 here; int64 only past
    # the 2**31 id cliff, see test_int64_ids.py) — both modes must agree
    assert lr.dtype == ls.dtype
    assert (np.asarray(lr) == np.asarray(ls)).all(), "x64 manifold"
    # itemsize doubles; the sharded-vs-replicated byte win must survive it
    assert int(ss.table_bytes_peak) < int(sr.table_bytes_peak)
    mask = jnp.asarray(rng.random(grid) < 0.5)
    lrc, _ = distributed_connected_components(mask, mesh, 6)
    lsc, _ = distributed_connected_components(mask, mesh, 6,
                                              table_mode="sharded")
    assert (np.asarray(lrc) == np.asarray(lsc)).all(), "x64 cc"
    print("SHARDED-X64-OK")
""")


@pytest.mark.slow
def test_sharded_int64_parity_under_x64():
    _run_worker(_X64_WORKER, "SHARDED-X64-OK", timeout=1800)


# --- slow: the full ragged seed corpus ---------------------------------------

_CORPUS_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys
    sys.path.insert(0, {tests_dir!r})
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components, compute_order)
    from repro.core.distributed_graph import (
        GraphDecomp, distributed_connected_components_graph)
    from oracles import (GRID_SEED_CORPUS, GRAPH_SEED_CORPUS,
                         ragged_grid_case, ragged_graph_case,
                         oracle_manifold, oracle_components,
                         oracle_components_graph)

    assert len(jax.devices()) == 8

    # sharded labels are compared to the pure-numpy oracles directly;
    # test_ragged_decomp.py pins replicated == oracle on the SAME corpus,
    # so sharded == replicated bit-parity follows transitively without
    # paying the replicated compile a second time (one XLA compile costs
    # ~30s on the 1-core CI runner)
    for seed in GRID_SEED_CORPUS:
        shape, layout, conn, mask_p = ragged_grid_case(seed)
        rng = np.random.default_rng(seed)
        mesh = make_dpc_mesh(layout)
        order = compute_order(jnp.asarray(rng.standard_normal(shape)))
        ls, ss = distributed_manifold(order, mesh, conn,
                                      table_mode="sharded")
        ref = oracle_manifold(np.asarray(order), conn)
        assert (np.asarray(ls).ravel() == ref.ravel()).all(), \\
            ("manifold", seed, shape, layout, conn)
        assert int(ss.converged) == 1, ("manifold-conv", seed)

        mask = jnp.asarray(rng.random(shape) < mask_p)
        lsc, sc = distributed_connected_components(mask, mesh, conn,
                                                   table_mode="sharded")
        refc = oracle_components(np.asarray(mask), conn)
        assert (np.asarray(lsc) == refc).all(), \\
            ("cc", seed, shape, layout, conn)
        assert int(sc.converged) == 1, ("cc-conv", seed)

    for seed in GRAPH_SEED_CORPUS:
        n, s, r, nparts, part, mask = ragged_graph_case(seed)
        dec = GraphDecomp(n, s, r, nparts, part=part)
        mesh = make_dpc_mesh(nparts)
        mj = jnp.asarray(mask)
        ls, ss = distributed_connected_components_graph(
            mj, dec, mesh, table_mode="sharded")
        ref = oracle_components_graph(mask, s, r)
        assert (np.asarray(ls) == ref).all(), ("graph", seed, nparts)
        assert int(ss.converged) == 1, ("graph-conv", seed)

    print("SHARDED-CORPUS-OK")
""").format(tests_dir=os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.slow
def test_sharded_full_corpus_parity():
    _run_worker(_CORPUS_WORKER, "SHARDED-CORPUS-OK", timeout=1800)
