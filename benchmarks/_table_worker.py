"""Worker for the table-scaling benchmark (deviation (s), DESIGN.md
§Table-sharding): replicated vs sharded boundary table at growing block
lattices, on 8 fake host devices in a subprocess (or the real multi-process
device set under ``--multihost``).  Prints CSV rows
``name,us_per_call,derived`` and writes ``BENCH_table.json`` with the
machine-comparable balance sheet: per-device table bytes, outer exchange
rounds and wall time for every (layout, kind, mode) cell — the artifact CI
archives so the memory/latency trade is tracked across runs."""
import os
import sys

if "--multihost" in sys.argv:
    # real multi-process mesh: the launcher provides coordinator env vars
    # (JAX_COORDINATOR_ADDRESS / process ids); never fake devices here
    import jax
    jax.distributed.initialize()
else:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import json
import time

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import compute_order, make_dpc_mesh
from repro.core.distributed import (distributed_manifold,
                                    distributed_connected_components)
from repro.data import perlin_noise

from _dpc_worker import _parse_size  # shared "edge or XxYxZ" spec parsing

# one grid, growing block lattice: the replicated table is the SAME size in
# every cell, so the per-device byte column isolates the sharding win
_LAYOUTS = ((2,), (2, 2), (2, 2, 2))


def timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def main():
    size = sys.argv[1]           # edge length or exact "XxYxZ" — verbatim
    dims = _parse_size(size)
    ndev = len(jax.devices())
    field = perlin_noise(dims, frequency=0.1, seed=0)
    order = compute_order(jnp.asarray(field))
    mask = jnp.asarray(field > np.quantile(field, 0.9))

    rows = []
    for layout in _LAYOUTS:
        if int(np.prod(layout)) > ndev:
            print(f"# table_scaling: skipping layout {layout} "
                  f"({ndev} devices)", file=sys.stderr)
            continue
        mesh = make_dpc_mesh(layout)
        tag = "x".join(map(str, layout))
        ref = {}
        for kind, fn, arg in (
                ("seg", distributed_manifold, order),
                ("cc", distributed_connected_components, mask)):
            for mode in ("replicated", "sharded"):
                us, (labels, stats) = timeit(
                    lambda a: fn(a, mesh, 6, table_mode=mode), arg)
                if mode == "replicated":
                    ref[kind] = np.asarray(labels)
                else:  # the bench is only meaningful if the modes agree
                    assert (np.asarray(labels) == ref[kind]).all(), \
                        (layout, kind)
                row = {"layout": tag, "kind": kind, "mode": mode,
                       "us_per_call": round(us),
                       "table_bytes_per_device": int(stats.table_bytes_peak),
                       "exchange_rounds": int(stats.exchange_rounds),
                       "comm_phases": int(stats.comm_phases),
                       "converged": int(stats.converged)}
                rows.append(row)
                print(f"table_scaling_{kind}_{mode}_{size}_{tag}blocks,"
                      f"{us:.0f},"
                      f"table_bytes={row['table_bytes_per_device']};"
                      f"exchange_rounds={row['exchange_rounds']};"
                      f"comm_phases={row['comm_phases']}", flush=True)

    out = os.path.join(os.getcwd(), "BENCH_table.json")
    with open(out, "w") as f:
        json.dump({"size": size, "n_devices": ndev, "rows": rows}, f,
                  indent=2)
        f.write("\n")
    print(f"# wrote {out} ({len(rows)} rows)", file=sys.stderr)


if __name__ == "__main__":
    main()
