"""Deterministic synthetic request workloads for the topology engine.

Shared by the throughput benchmark (`benchmarks/run.py serve_throughput`),
the serving launcher (`python -m repro.launch.serve --topology`) and the
runnable demo (`examples/serve_topology.py`): a seeded mix of CC /
MS-segmentation / threshold-sweep requests over a rotating set of grid
extents — the "many small heterogeneous tenants" traffic shape the engine
buckets.  Every request is a pure function of (seed, index), so repeated
workloads exercise the executable cache the way real repeated-layout
traffic does.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..core.ids import compute_order
from ..topology import TopologyRequest


def synthetic_requests(n_requests: int, shapes, mix=None, connectivity=6,
                       sweep_k: int = 4, seed: int = 0, backend: str = "pure",
                       mesh=None) -> list:
    """A deterministic list of mixed TopologyRequests.

    shapes: tuple of grid extents to rotate through; mix: tuple of
    (query, weight) over {"cc", "ms", "manifold", "threshold_sweep"}.
    """
    mix = mix or (("cc", 0.5), ("ms", 0.2), ("manifold", 0.1),
                  ("threshold_sweep", 0.2))
    queries = [q for q, _ in mix]
    weights = np.asarray([w for _, w in mix], dtype=float)
    weights = weights / weights.sum()
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_requests):
        shape = shapes[int(rng.integers(len(shapes)))]
        query = queries[int(rng.choice(len(queries), p=weights))]
        field = rng.standard_normal(shape)
        common = dict(connectivity=connectivity, backend=backend, mesh=mesh,
                      tag=i)
        if query == "cc":
            reqs.append(TopologyRequest(
                "cc", mask=jnp.asarray(field > rng.uniform(-0.5, 0.5)),
                **common))
        elif query in ("ms", "manifold"):
            reqs.append(TopologyRequest(
                query, order=compute_order(jnp.asarray(field)),
                descending=bool(i % 2), **common))
        else:
            thr = np.quantile(field, np.linspace(0.2, 0.9, sweep_k))
            reqs.append(TopologyRequest(
                "threshold_sweep", field=jnp.asarray(field),
                thresholds=jnp.asarray(thr), **common))
    return reqs
