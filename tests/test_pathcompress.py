"""Unit + property tests for the pointer-doubling primitive.

The property tests run under hypothesis when it is installed; otherwise the
same checks run on a fixed seed sweep (plain parametrized cases), so the
suite collects and passes in a minimal environment."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import path_compress, jump, is_converged


def _np_compress(d):
    d = d.copy()
    n = len(d)
    for v in range(n):
        if d[v] < 0:
            continue
        cur = v
        seen = 0
        while d[cur] != cur:
            cur = d[cur]
            seen += 1
            assert seen <= n, "cycle"
        d[v] = cur
    return d


def test_chain():
    # 0<-1<-2<-...<-9 : everything compresses to 0
    d = jnp.array([0, 0, 1, 2, 3, 4, 5, 6, 7, 8])
    out, iters = path_compress(d)
    assert (np.asarray(out) == 0).all()
    assert int(iters) <= 5  # log2(10) rounds + convergence check


def test_masked_entries_fixed():
    d = jnp.array([-1, 1, 1, -1, 4, 4])
    out, _ = path_compress(d)
    np.testing.assert_array_equal(np.asarray(out), [-1, 1, 1, -1, 4, 4])


def test_already_converged():
    d = jnp.arange(8)
    out, iters = path_compress(d)
    np.testing.assert_array_equal(np.asarray(out), np.arange(8))
    assert int(iters) == 1  # one round to detect the fixpoint
    assert bool(is_converged(d))


def _make_forest(n, seed):
    """Random functional forest: d[v] >= v points 'up' toward roots;
    masked (-1) vertices are never pointer targets (the DPC invariant)."""
    rng = np.random.default_rng(seed)
    masked = rng.random(n) < 0.15
    live = np.flatnonzero(~masked)
    d = np.full(n, -1, dtype=np.int64)
    d[live] = live  # roots by default
    for i, v in enumerate(live[:-1]):
        if rng.random() < 0.8:
            d[v] = rng.choice(live[i + 1:])  # strictly increasing -> acyclic
    return d


def _check_matches_sequential(d):
    out, _ = path_compress(jnp.asarray(d))
    np.testing.assert_array_equal(np.asarray(out), _np_compress(d))


def _check_idempotent(d):
    out, _ = path_compress(jnp.asarray(d))
    out2, iters2 = path_compress(out)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    assert int(iters2) == 1


if HAVE_HYPOTHESIS:
    @st.composite
    def pointer_forest(draw):
        return _make_forest(draw(st.integers(2, 200)),
                            draw(st.integers(0, 2**31 - 1)))

    @given(pointer_forest())
    @settings(max_examples=50, deadline=None)
    def test_property_matches_sequential(d):
        _check_matches_sequential(d)

    @given(pointer_forest())
    @settings(max_examples=25, deadline=None)
    def test_property_idempotent(d):
        _check_idempotent(d)
else:
    @pytest.mark.parametrize("seed", range(25))
    def test_property_matches_sequential(seed):
        n = int(np.random.default_rng(1000 + seed).integers(2, 200))
        _check_matches_sequential(_make_forest(n, seed))

    @pytest.mark.parametrize("seed", range(10))
    def test_property_idempotent(seed):
        n = int(np.random.default_rng(2000 + seed).integers(2, 200))
        _check_idempotent(_make_forest(n, seed))


def test_log_rounds():
    # chain of 2**k resolves in ~k+1 rounds — the paper's core scaling claim
    n = 1024
    d = jnp.concatenate([jnp.zeros(1, jnp.int32),
                         jnp.arange(n - 1, dtype=jnp.int32)])
    _, iters = path_compress(d)
    assert int(iters) <= 12
