"""minitron-8b [dense]: 32L d_model=4096 32H (GQA kv=8) d_ff=16384
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]"""
import jax.numpy as jnp

from repro.models.lm import LMConfig
from .lm_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name="minitron-8b", n_layers=32, d_model=4096, n_heads=32,
        n_kv_heads=8, d_ff=16384, vocab=256000,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="minitron-8b-smoke", n_layers=2, d_model=64, n_heads=16,
        n_kv_heads=4, d_ff=256, vocab=256, d_head=4, loss_chunks=2)
