"""Connected components via path compression (paper Alg. 3).

Pointer init: largest masked neighbor id (incl. self); unmasked vertices are
labeled -1 and excluded.  After a first compression, sub-segments (one per
local id-maximum) are merged by the *stitch* pass
    d[d[v]] <- max over masked neighbors u of d[u]
followed by another compression.

Deviation (d) in DESIGN.md: the paper presents a single stitch+compress pass;
a chain of sub-segments whose roots only become hookable after earlier merges
requires iteration, so we run stitch+compress to a fixpoint inside a
`lax.while_loop` (<= log2 #subsegments rounds; 1-2 in practice, matching the
paper's observed behaviour).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .pathcompress import path_compress, jump
from .steepest import graph_mask_argmax, neighbor_offsets, shift_fill


class CCResult(NamedTuple):
    labels: jax.Array      # largest vertex id of the component; -1 unmasked
    n_rounds: jax.Array    # stitch rounds executed
    n_compress_iter: jax.Array


def _grid_stitch(d: jax.Array, mask_flat: jax.Array, shape, connectivity: int,
                 sentinel: int) -> jax.Array:
    """One stitch pass (Alg. 3 lines 25-29) on a structured grid, as a
    scatter-max: for each directed neighbor pair (v, u) with both masked,
    d[d[v]] <- max(d[d[v]], d[u])."""
    d_grid = d.reshape(shape)
    m_grid = mask_flat.reshape(shape)
    out = d
    for off in neighbor_offsets(len(shape), connectivity):
        u_label = shift_fill(d_grid, off, -1).ravel()          # d[u]
        valid = mask_flat & (shift_fill(m_grid, off, False).ravel())
        tgt = jnp.where(valid, d, sentinel)                    # index d[v]
        val = jnp.where(valid, u_label, -1)
        out = out.at[tgt].max(val, mode="drop")
    return out


def _graph_stitch(d: jax.Array, mask: jax.Array, senders: jax.Array,
                  receivers: jax.Array, sentinel: int) -> jax.Array:
    valid = mask[senders] & mask[receivers]
    tgt = jnp.where(valid, d[senders], sentinel)
    val = jnp.where(valid, d[receivers], -1)
    return d.at[tgt].max(val, mode="drop")


def _cc_fixpoint(d0: jax.Array, stitch_fn, max_rounds: int = 64) -> CCResult:
    d, it0 = path_compress(d0)

    def cond(state):
        _, changed, r, _ = state
        return changed & (r < max_rounds)

    def body(state):
        cur, _, r, its = state
        stitched = stitch_fn(cur)
        compressed, it = path_compress(stitched)
        return (compressed, jnp.any(compressed != cur), r + jnp.int32(1),
                its + it)

    d, _, rounds, its = lax.while_loop(
        cond, body, (d, jnp.asarray(True), jnp.int32(0), it0)
    )
    return CCResult(d, rounds, its)


@partial(jax.jit, static_argnames=("connectivity", "fused_impl"))
def connected_components_grid(mask: jax.Array, connectivity: int = 6,
                              fused_impl: str = "auto") -> CCResult:
    """Mask-implicit connected components on a structured grid.

    The mask plays the paper's feature-mask role (e.g. thresholded scalar
    field); the grid is never extracted — non-feature vertices just carry -1
    (the paper's "implicitly thresholded grids", §5).  fused_impl selects
    the pointer-init implementation (repro.kernels.ops.fused_local_phase);
    labels are bit-identical across choices — the kernel path merely starts
    the first compression near-converged.
    """
    # lazy: repro.kernels imports repro.core.steepest at module load
    from repro.kernels.ops import fused_local_phase
    n = mask.size
    mask_flat = mask.ravel().astype(bool)
    d0, _ = fused_local_phase(mask, connectivity, mode="cc", impl=fused_impl)
    stitch = lambda d: _grid_stitch(d, mask_flat, mask.shape, connectivity, n)
    res = _cc_fixpoint(d0.ravel(), stitch)
    return CCResult(res.labels.reshape(mask.shape), res.n_rounds,
                    res.n_compress_iter)


@jax.jit
def connected_components_graph(mask: jax.Array, senders: jax.Array,
                               receivers: jax.Array) -> CCResult:
    """Mask-implicit connected components on an edge-list graph.  Pass both
    edge directions for undirected graphs.  mask=ones labels pure geometry
    (paper: CC "computed on pure geometry without any scalar data")."""
    n = mask.shape[0]
    d0 = graph_mask_argmax(mask, senders, receivers)
    stitch = lambda d: _graph_stitch(d, mask.astype(bool), senders, receivers, n)
    return _cc_fixpoint(d0, stitch)


def component_sizes(labels: jax.Array, num_segments: int | None = None):
    """Histogram of component sizes keyed by root id (unmasked dropped)."""
    flat = labels.ravel()
    # `is None`, not truthiness: an explicit num_segments=0 (empty label
    # space) must yield an empty histogram, not fall back to flat.shape[0]
    n = flat.shape[0] if num_segments is None else num_segments
    seg = jnp.where(flat >= 0, flat, n)  # park unmasked in a dropped bucket
    return jax.ops.segment_sum(
        jnp.ones_like(flat), seg, num_segments=n + 1
    )[:n]
