"""Connected components (Alg. 3) vs BFS oracle, incl. the stitch-iteration
counter-example motivating deviation (d) in DESIGN.md.

Property tests run under hypothesis when installed, else on a fixed seed
sweep (plain parametrized cases)."""
import numpy as np
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import (connected_components_grid, connected_components_graph,
                        component_sizes, label_propagation_grid)
from repro.data import perlin_noise
from oracles import oracle_components, oracle_components_graph, grid_neighbors


@pytest.mark.parametrize("shape,conn,p,seed", [
    ((16, 17), 4, 0.5, 0), ((16, 17), 6, 0.5, 1),
    ((8, 9, 10), 6, 0.4, 2), ((8, 9, 10), 14, 0.3, 3),
    ((30, 31), 4, 0.7, 4), ((30, 31), 4, 0.05, 5),
])
def test_grid_cc_matches_oracle(shape, conn, p, seed):
    rng = np.random.default_rng(seed)
    mask = rng.random(shape) < p
    res = connected_components_grid(jnp.asarray(mask), conn)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  oracle_components(mask, conn))


def test_all_masked_single_grid():
    mask = np.ones((11, 12), bool)
    res = connected_components_grid(jnp.asarray(mask), 4)
    assert (np.asarray(res.labels) == 11 * 12 - 1).all()


def test_none_masked():
    mask = np.zeros((6, 6), bool)
    res = connected_components_grid(jnp.asarray(mask), 4)
    assert (np.asarray(res.labels) == -1).all()


def test_stitch_needs_iteration():
    """Adversarial id layout: a one-pass stitch (paper Alg. 3 as written)
    leaves a component split; our fixpoint loop must resolve it.

    Construct a snake whose sub-segment roots only become hookable after
    earlier merges (see DESIGN.md deviation (d))."""
    # 1D-ish snake in a 2D grid with crafted ids via grid layout:
    # row-major ids; component zig-zags so id-maxima alternate.
    mask = np.zeros((9, 9), bool)
    mask[0, :] = True
    mask[:, 0] = True
    mask[8, :] = True
    mask[:, 8] = True  # ring: one component
    res = connected_components_grid(jnp.asarray(mask), 4)
    labels = np.asarray(res.labels)
    assert np.unique(labels[mask]).size == 1
    assert labels[mask].max() == labels[mask].min() == 8 * 9 + 8


def _check_random_grid(seed, p):
    rng = np.random.default_rng(seed)
    mask = rng.random((12, 13)) < p
    res = connected_components_grid(jnp.asarray(mask), 4)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  oracle_components(mask, 4))


def _check_graph_cc(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(5, 120))
    m = int(rng.integers(0, 4 * n))
    s = rng.integers(0, n, m)
    r = rng.integers(0, n, m)
    senders = np.concatenate([s, r])
    receivers = np.concatenate([r, s])
    mask = rng.random(n) < 0.7
    res = connected_components_graph(
        jnp.asarray(mask), jnp.asarray(senders), jnp.asarray(receivers))
    np.testing.assert_array_equal(
        np.asarray(res.labels), oracle_components_graph(mask, senders, receivers))


if HAVE_HYPOTHESIS:
    @given(st.integers(0, 2**31 - 1), st.floats(0.1, 0.9))
    @settings(max_examples=30, deadline=None)
    def test_property_random_grids(seed, p):
        _check_random_grid(seed, p)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_graph_cc(seed):
        _check_graph_cc(seed)
else:
    @pytest.mark.parametrize("seed,p", [(s, p) for s in range(5)
                                        for p in (0.15, 0.4, 0.6, 0.85)])
    def test_property_random_grids(seed, p):
        _check_random_grid(seed, p)

    @pytest.mark.parametrize("seed", range(12))
    def test_property_graph_cc(seed):
        _check_graph_cc(seed)


def test_component_sizes():
    mask = np.zeros((4, 4), bool)
    mask[0, 0:2] = True   # size 2
    mask[3, 3] = True     # size 1
    res = connected_components_grid(jnp.asarray(mask), 4)
    sizes = np.asarray(component_sizes(res.labels))
    labels = np.asarray(res.labels)
    assert sizes[labels[0, 0]] == 2
    assert sizes[labels[3, 3]] == 1
    assert sizes.sum() == 3


def test_component_sizes_explicit_num_segments():
    labels = jnp.asarray([-1, 2, 2, 0])
    sizes = np.asarray(component_sizes(labels, num_segments=4))
    assert sizes.shape == (4,)
    assert sizes[2] == 2 and sizes[0] == 1 and sizes.sum() == 3
    # an explicit num_segments=0 means an empty histogram — it must not be
    # treated as unset (truthiness bug) and fall back to labels.size
    assert np.asarray(component_sizes(labels, num_segments=0)).shape == (0,)


def test_perlin_threshold_cc_matches_baseline():
    """DPC-CC == label-propagation baseline (the VTK stand-in) on the
    paper's Perlin workload; DPC needs far fewer rounds (log vs diameter)."""
    field = perlin_noise((20, 20, 20), frequency=0.12, seed=7)
    mask = field > np.quantile(field, 0.9)   # paper's "top 10%" thresholding
    dpc = connected_components_grid(jnp.asarray(mask), 6)
    base = label_propagation_grid(jnp.asarray(mask), 6)
    np.testing.assert_array_equal(np.asarray(dpc.labels),
                                  np.asarray(base.labels))
