"""Path compression (pointer doubling) — the paper's core primitive.

Shared-memory Alg. 1 lines 9-19 (Maack et al. [33]) adapted to TPU:
per-thread active lists become whole-array functional gathers
`d_{t+1}[v] = d_t[d_t[v]]`; the while-loop convergence check replaces
active-list deletion.  Each round doubles every pointer-chain length, so a
chain of length L resolves in ceil(log2 L) rounds.  Entries < 0 are
"unmasked" sentinels (paper Alg. 3 line 12) and are left untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def jump(d: jax.Array) -> jax.Array:
    """One pointer-doubling round: d[v] <- d[d[v]], masked entries fixed."""
    nd = jnp.take(d, jnp.clip(d, 0), axis=0)
    return jnp.where(d >= 0, nd, d)


def path_compress(d: jax.Array, max_iter: int = 64):
    """Iterate pointer doubling to the fixpoint.

    Args:
      d: int array of pointers into itself (flat), -1 for unmasked entries.
      max_iter: safety bound; 64 covers any chain up to 2**64.

    Returns:
      (compressed pointers, number of rounds executed).
    """
    def cond(state):
        _, changed, i = state
        return changed & (i < max_iter)

    def body(state):
        cur, _, i = state
        nxt = jump(cur)
        return nxt, jnp.any(nxt != cur), i + jnp.int32(1)

    out, _, iters = lax.while_loop(
        cond, body, (d, jnp.asarray(True), jnp.int32(0))
    )
    return out, iters


def path_compress_unrolled(d: jax.Array, rounds: int) -> jax.Array:
    """Fixed number of doubling rounds (for kernels / known-diameter blocks)."""
    for _ in range(rounds):
        d = jump(d)
    return d


def is_converged(d: jax.Array) -> jax.Array:
    """True iff every masked pointer is a fixpoint (points at a root)."""
    return jnp.all(jump(d) == d)
