"""The dry-run deliverable: every (arch x shape) cell must have compiled on
BOTH production meshes, with sane analysis records."""
import json
import os

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_DRY = os.path.join(_ROOT, "experiments", "dryrun")


def _cells():
    import sys
    sys.path.insert(0, os.path.join(_ROOT, "src"))
    from repro import configs
    out = []
    for arch in configs.ARCH_IDS:
        for shape in configs.get(arch).SHAPES:
            out.append((arch, shape))
    return out


@pytest.mark.parametrize("mesh", ["pod256", "pod2x256"])
def test_dryrun_matrix_complete(mesh):
    if not os.path.isdir(os.path.join(_DRY, mesh)):
        pytest.skip("dry-run artifacts not generated yet "
                    "(run python -m repro.launch.dryrun)")
    missing = []
    for arch, shape in _cells():
        p = os.path.join(_DRY, mesh,
                         f"{arch.replace('-', '_')}__{shape}.json")
        if not os.path.exists(p):
            missing.append((arch, shape))
            continue
        with open(p) as f:
            rec = json.load(f)
        assert rec["cost"].get("flops", 0) > 0, (arch, shape)
        assert rec["memory"]["argument_size_in_bytes"] > 0, (arch, shape)
        assert not rec["smoke"], (arch, shape, "smoke record in real dir")
    assert not missing, f"{len(missing)} cells missing on {mesh}: {missing}"


def test_multi_pod_actually_uses_pod_axis():
    """The pod axis must shard: per-device argument bytes on 2x256 must not
    exceed the 1x256 bytes for the big train cells (state is sharded over
    dp=pod x data)."""
    pairs = [("kimi_k2_1t", "train_4k"), ("stablelm_12b", "train_4k")]
    for arch, shape in pairs:
        recs = {}
        for mesh in ("pod256", "pod2x256"):
            p = os.path.join(_DRY, mesh, f"{arch}__{shape}.json")
            if not os.path.exists(p):
                pytest.skip("dry-run artifacts not generated yet")
            with open(p) as f:
                recs[mesh] = json.load(f)
        a1 = recs["pod256"]["memory"]["argument_size_in_bytes"]
        a2 = recs["pod2x256"]["memory"]["argument_size_in_bytes"]
        assert a2 <= a1 * 1.05, (arch, a1, a2)
