"""dpc-graph — the paper's *unstructured* workload: distributed connected
components on edge-list meshes (paper §5: CC "in distributed structured and
unstructured grids, based either on the connectivity of the underlying mesh
or a feature mask").

Two mesh families:
  * tet_* / geometry_* — the Kuhn/Freudenthal tetrahedralization of an n^3
    grid emitted as a fully unstructured edge list (connectivity 14), i.e.
    a synthetic tet mesh with a known oracle; `geometry_*` runs the
    mask=ones pure-geometry variant (no scalar data);
  * random_* — random sparse graphs (the adversarial partition-adjacency
    case: every partition may touch every other).

The vertex partition is 1-D (contiguous global-id blocks over the
flattened device mesh).  Vertex counts need not divide the device count:
imbalanced partitions pad their owned sets with inert sentinels
(deviation (p) in DESIGN.md), so prime-sized meshes lower on both
production meshes too.
"""
import dataclasses

FAMILY = "dpc_graph"


@dataclasses.dataclass(frozen=True)
class DPCGraphConfig:
    name: str = "dpc-graph"
    connectivity: int = 14            # Freudenthal/tet edge set (3-D grids)
    threshold_quantile: float = 0.9   # paper's "top 10%" feature mask
    arch: str = "dpc_graph"
    # §Perf (DESIGN.md): drop the redundant mask all_gather (M = T >= 0)
    gather_mask: bool = True


SHAPES = {
    "tet_64": {"kind": "graph_cc", "dims": (64, 64, 64)},
    "tet_32": {"kind": "graph_cc", "dims": (32, 32, 32)},
    "geometry_32": {"kind": "graph_cc", "dims": (32, 32, 32),
                    "geometry": True},
    "random_1m": {"kind": "graph_cc_random", "n": 1 << 20, "avg_degree": 8},
    # prime vertex count: an imbalanced (padded) partition on every mesh
    "tet_ragged": {"kind": "graph_cc", "dims": (61, 43, 29)},
}

# smoke vertex counts need not divide the 256/512-way flat meshes (padded
# owned sets, deviation (p) in DESIGN.md); tet_ragged keeps a prime count
SMOKE_SHAPES = {
    "tet_64": {"kind": "graph_cc", "dims": (8, 8, 8)},
    "tet_32": {"kind": "graph_cc", "dims": (8, 8, 8)},
    "geometry_32": {"kind": "graph_cc", "dims": (8, 8, 8), "geometry": True},
    "random_1m": {"kind": "graph_cc_random", "n": 4096, "avg_degree": 8},
    "tet_ragged": {"kind": "graph_cc", "dims": (7, 7, 7)},
}

# partition counts exercised by the graph-CC strong-scaling benchmark
SCALING_PARTS = (1, 2, 4, 8)


def full_config() -> DPCGraphConfig:
    return DPCGraphConfig()


def smoke_config() -> DPCGraphConfig:
    return DPCGraphConfig(name="dpc-graph-smoke")
