"""Pallas TPU kernel: in-VMEM block path compression.

TPU adaptation of the paper's thread-local compression: right after the
steepest init every pointer targets a direct neighbor, so the first K
doubling rounds stay almost entirely inside an x-slab.  Running those rounds
on a VMEM-resident tile costs one HBM read + one write for K rounds, versus
K full HBM round-trips for global `d <- d[d]` gathers (each of which moves
8 bytes/vertex/round at 819 GB/s).  Out-of-block and negative pointers are
fixed points, exactly like ghost vertices in Alg. 1 — the block boundary IS
a ghost boundary, so correctness follows from the same argument as the
distributed algorithm, and the remaining global rounds finish the job.

Arrays whose length does not divide the tile size take a ceil-division
grid: the input is padded up to it with the sentinel -1, which the kernel
treats as a fixed point, so the clamped last tile never reads past the
ragged extent (pad-and-mask, deviation (p) in DESIGN.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(d_ref, out_ref, *, rounds, block):
    i = pl.program_id(0)
    base = i * block
    d = d_ref[...]
    for _ in range(rounds):
        local = d - base
        in_block = (d >= 0) & (local >= 0) & (local < block)
        nd = jnp.take(d, jnp.clip(local, 0, block - 1), axis=0)
        d = jnp.where(in_block, nd, d)
    out_ref[...] = d


def _next_pow2(n: int) -> int:
    """Engine bucket capacity (serve.bucketing.next_pow2, re-derived here to
    keep kernels import-independent of the serving layer)."""
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


@functools.partial(jax.jit,
                   static_argnames=("rounds", "block", "interpret"))
def _padded_call(d: jax.Array, rounds: int, block: int,
                 interpret: bool) -> jax.Array:
    """The jitted pallas program over an already-bucketed length: its cache
    keys on (capacity, block, rounds, dtype) only."""
    n = d.shape[0]
    n_tiles = -(-n // block)          # ceil: the last tile may be ragged
    n_pad = n_tiles * block
    if n_pad != n:
        d = jnp.pad(d, (0, n_pad - n), constant_values=-1)
    kernel = functools.partial(_kernel, rounds=rounds, block=block)
    out = pl.pallas_call(
        kernel,
        grid=(n_tiles,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), d.dtype),
        interpret=interpret,
    )(d)
    return out[:n] if n_pad != n else out


def block_pathcompress(d: jax.Array, rounds: int = 4, block: int = 4096,
                       interpret: bool = True) -> jax.Array:
    """K pointer-doubling rounds confined to `block`-sized tiles.

    d: (N,) int32 global pointers (any N; ragged tiles are padded with the
    -1 sentinel and sliced back off).  The length is snapped to the serving
    engine's power-of-two bucket capacities OUTSIDE the jit boundary —
    `min(block, n)` used to bake the raw request length into the traced
    shape, so every distinct length compiled a fresh executable; now any n
    in (cap/2, cap] reuses one per-(capacity, block, dtype) executable, at
    the cost of at most one extra tile's worth of inert -1 work.
    """
    n = d.shape[0]
    cap = _next_pow2(n)
    block = min(block, cap)
    if cap != n:
        d = jnp.pad(d, (0, cap - n), constant_values=-1)
    out = _padded_call(d, rounds, block, interpret)
    return out[:n] if cap != n else out
