"""Batched multi-tenant topology serving (DESIGN.md §Serve).

    from repro.serve import TopologyEngine
    from repro.topology import TopologyRequest

    eng = TopologyEngine()
    results = eng.submit_batch([TopologyRequest("cc", mask=m), ...])
    eng.stats.as_dict()   # requests/batches, cache hit rate, pad waste
"""
from .engine import TopologyEngine, EngineStats
from .bucketing import bucket_shape, batch_capacity, remap_flat_labels

__all__ = ["TopologyEngine", "EngineStats", "bucket_shape",
           "batch_capacity", "remap_flat_labels"]
