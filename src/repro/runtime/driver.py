"""Fault-tolerant training driver.

Production contract for thousands of nodes:
  * periodic async checkpoints (atomic; survives SIGKILL mid-write);
  * automatic restore-from-latest + data-stream seek on restart — a node
    failure costs at most `ckpt_every` steps of recompute;
  * failure injection hooks so the restart path is *tested*, not vestigial;
  * straggler monitor: per-step wall-time EWMA; steps slower than
    `straggler_factor` x EWMA are logged and counted (on real pods this
    feeds the collective-timeout / hot-swap machinery; here it drives tests
    and benchmarks);
  * elastic re-mesh: restore() re-places arrays under the *current* mesh's
    shardings, so a resumed run may use a different device count.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Iterator, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


class InjectedFailure(RuntimeError):
    """Simulated node failure (tests and chaos benchmarks)."""


@dataclasses.dataclass
class StragglerStats:
    ewma: float = 0.0
    count: int = 0
    worst: float = 0.0


@dataclasses.dataclass
class TrainDriver:
    step_fn: Callable            # (state, batch) -> (state, metrics)
    init_state: Any              # pytree (params, opt_state, ...)
    make_data: Callable[[int], Iterator]   # start_step -> iterator
    ckpt: CheckpointManager
    ckpt_every: int = 50
    max_restarts: int = 3
    failure_injector: Optional[Callable[[int], bool]] = None
    straggler_factor: float = 3.0
    log_every: int = 10
    verbose: bool = True

    def _log(self, *a):
        if self.verbose:
            print("[driver]", *a, flush=True)

    def run(self, n_steps: int):
        """Run to `n_steps` total (absolute), restarting on failures."""
        restarts = 0
        straggler = StragglerStats()
        state, start = self._restore_or_init()
        while True:
            try:
                state, start = self._run_from(state, start, n_steps,
                                              straggler)
                self.ckpt.wait()
                return state, {"restarts": restarts,
                               "stragglers": straggler.count,
                               "worst_step_ratio": straggler.worst}
            except InjectedFailure as e:
                restarts += 1
                self._log(f"FAILURE at step {start}: {e}; "
                          f"restart {restarts}/{self.max_restarts}")
                if restarts > self.max_restarts:
                    raise
                state, start = self._restore_or_init()

    def _restore_or_init(self):
        restored, manifest = self.ckpt.restore(self.init_state)
        if restored is None:
            return self.init_state, 0
        step = int(manifest["step"])
        self._log(f"restored checkpoint at step {step}")
        return restored, step

    def _run_from(self, state, start: int, n_steps: int,
                  straggler: StragglerStats):
        data = self.make_data(start)
        for step in range(start, n_steps):
            if self.failure_injector and self.failure_injector(step):
                raise InjectedFailure(f"injected at step {step}")
            batch = next(data)
            t0 = time.perf_counter()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics)
            dt = time.perf_counter() - t0
            # straggler detection (EWMA of steady-state step time)
            if step > start + 2:  # skip compile steps
                if straggler.ewma == 0.0:
                    straggler.ewma = dt
                ratio = dt / straggler.ewma
                if ratio > self.straggler_factor:
                    straggler.count += 1
                    straggler.worst = max(straggler.worst, ratio)
                    self._log(f"straggler step {step}: {dt * 1e3:.1f}ms "
                              f"({ratio:.1f}x EWMA)")
                straggler.ewma = 0.9 * straggler.ewma + 0.1 * dt
            if self.log_every and step % self.log_every == 0:
                flat = {k: float(np.asarray(v))
                        for k, v in metrics.items()
                        if np.ndim(v) == 0}
                self._log(f"step {step}: {flat}")
            if (step + 1) % self.ckpt_every == 0:
                self.ckpt.save(step + 1, state)
        return state, n_steps
