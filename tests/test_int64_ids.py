"""int64 id path (ROADMAP open item): grids/graphs with >= 2**31 vertices
must take int64 global ids under `jax_enable_x64` and refuse loudly without
it — never wrap silently.  Exercised on synthetic small-extent/large-stride
decompositions whose *flat ids* overflow int32 without ever allocating a
real >= 2048^3 array (the id maps are closed-form / table-sized)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core import BlockDecomp, GraphDecomp

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def test_block_decomp_refuses_int64_without_x64():
    import jax
    assert not jax.config.jax_enable_x64  # test-process invariant
    with pytest.raises(ValueError, match="jax_enable_x64"):
        BlockDecomp((2048, 2048, 2048), (2,), ("shards",))


def test_graph_decomp_refuses_int64_without_x64():
    import jax
    assert not jax.config.jax_enable_x64
    with pytest.raises(ValueError, match="jax_enable_x64"):
        GraphDecomp(2**31, [], [], 2)


def test_int32_grids_keep_int32_ids():
    import jax.numpy as jnp
    dec = BlockDecomp((8, 8, 8), (2,), ("shards",))
    assert dec.id_dtype == jnp.int32


_X64_WORKER = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import BlockDecomp

    assert jax.config.jax_enable_x64

    # A: 3-D grid of 2**32 vertices, slab layout: ids span the int32 cliff
    dec = BlockDecomp((2**20, 2**7, 2**5), (4,), ("shards",))
    assert dec.id_dtype == jnp.int64
    assert dec.size == 2**32
    coords = dec.slot_coords(np).astype(np.int64)
    g = (coords * np.asarray(dec.stride, np.int64)).sum(axis=1)
    assert g.max() > 2**31, "table must contain post-int32 ids"
    is_b, pos = dec.boundary_pos(g, np)
    assert is_b.all(), "every table slot is a boundary vertex"
    assert (pos == np.arange(dec.table_size)).all(), "slot round-trip"
    # interior vertices (strictly inside a block along the cut axis) are
    # not boundary, even with ids past 2**31
    xs0 = np.array([5, dec.local[0] + 7, 3 * dec.local[0] + 2], np.int64)
    interior = xs0 * dec.stride[0] + 3 * dec.stride[1] + 2
    is_b, _ = dec.boundary_pos(interior, np)
    assert not is_b.any()

    # B: 2-D grid of 2**32 vertices, 2x2 block lattice: block corners must
    # canonicalise to the lowest decomposed axis
    dec2 = BlockDecomp((2**16, 2**16), (2, 2), ("bx", "by"))
    assert dec2.id_dtype == jnp.int64
    c2 = dec2.slot_coords(np).astype(np.int64)
    g2 = (c2 * np.asarray(dec2.stride, np.int64)).sum(axis=1)
    is_b2, pos2 = dec2.boundary_pos(g2, np)
    assert is_b2.all()
    # corner slots appear under BOTH axes' faces; boundary_pos must map the
    # axis-1 copies back to their canonical axis-0 slot
    slots = np.arange(dec2.table_size)
    ax0 = slots < dec2.face_offset[1]
    assert (pos2[ax0] == slots[ax0]).all()
    L0, L1 = dec2.local
    on_ax0 = (c2[:, 0] % L0 == 0) | (c2[:, 0] % L0 == L0 - 1)
    dup = ~ax0 & on_ax0            # axis-1 slot of an axis-0 boundary vertex
    assert dup.any()
    assert (pos2[dup] < dec2.face_offset[1]).all(), "canonicalised to axis 0"
    assert (pos2[~ax0 & ~on_ax0] == slots[~ax0 & ~on_ax0]).all()
    assert g2.max() == 2**32 - 1   # the global corner sits in the table

    print("X64-OK")
""")


@pytest.mark.parametrize("mode", ["x64"])
def test_int64_ids_under_x64(mode):
    """Subprocess: the x64 flag is global, so the int64 assertions must not
    leak into this (x64-off) test process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _X64_WORKER], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "X64-OK" in proc.stdout
