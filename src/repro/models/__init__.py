from . import lm
