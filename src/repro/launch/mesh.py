"""Production meshes.  Functions (not module constants) so importing never
touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_flat_mesh(mesh=None, name: str = "shards"):
    """1-D view over the same devices — the DPC slab axis."""
    if mesh is None:
        mesh = make_production_mesh()
    devices = mesh.devices.reshape(-1)
    return jax.make_mesh((devices.size,), (name,), devices=devices)


def make_block_mesh(layout, mesh=None):
    """N-D view over the same devices — the DPC block lattice.

    layout: per-axis block counts, e.g. (4, 2) or (2, 2, 2); mesh axis a
    decomposes grid axis a (axis names bx/by/bz).  Reuses the devices of
    `mesh` (default: the production mesh) so the DPC workload can share a
    pod with training jobs; total layout size must match the device count.
    """
    import math

    from repro.core import make_dpc_mesh
    if mesh is None:
        mesh = make_production_mesh()
    devices = list(mesh.devices.reshape(-1))
    layout = tuple(int(p) for p in layout)
    if math.prod(layout) != len(devices):
        raise ValueError(f"layout {layout} needs {math.prod(layout)} devices"
                         f" but mesh has {len(devices)}")
    return make_dpc_mesh(layout, devices=devices)


def make_smoke_mesh(n: int | None = None):
    """Whatever this host has (tests / examples)."""
    n = n or len(jax.devices())
    shape = (1, n) if n > 1 else (1, 1)
    return jax.make_mesh(shape, ("data", "model"))
