"""Serving topology queries: the batched multi-tenant engine in 60 seconds.

Builds a mixed workload (CC masks, Morse-Smale segmentations, manifold
queries, threshold sweeps, over several ragged grid extents), serves it
through `repro.serve.TopologyEngine`, and checks the contracts from
DESIGN.md §Serve / §Serve-v2:

  1. every batched result is bit-identical to the sequential
     `repro.topology.submit` path,
  2. replaying the same layouts compiles nothing new — the second bucket
     occupant is served from the executable cache (hit rate > 0), and
  3. the async deadline-aware plane (queueing, capacity/deadline flushes
     on a virtual clock) returns the SAME bits through future-style
     handles, from a workload trace replayable by its seed alone.

  PYTHONPATH=src python examples/serve_topology.py
"""
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro import configs
from repro.topology import submit_many
from repro.serve import TopologyEngine
from repro.serve.workload import synthetic_trace

cfg = configs.get("serve_topology").smoke_config()
# the trace IS the workload: seed + parameters regenerate identical
# requests anywhere (drop trace.as_dict() in a bug report to replay it)
trace = synthetic_trace(10, cfg.shapes, mix=cfg.mix,
                        connectivity=cfg.connectivity,
                        sweep_k=cfg.sweep_k, seed=0,
                        rate=cfg.rate, deadline_slack=cfg.deadline_slack)
reqs = trace.requests()
print(f"workload: {len(reqs)} requests over extents "
      f"{sorted({r.shape() for r in reqs})}")

eng = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch)
t0 = time.perf_counter()
batched = eng.submit_batch(reqs)
t_batched = time.perf_counter() - t0
s = eng.stats
print(f"cold pass: {len(reqs)} requests -> {s.items} items -> "
      f"{s.batches} executions in {t_batched * 1e3:.0f}ms "
      f"(pad_fraction={s.pad_fraction:.2f})")

# contract 1: bit-identical to the sequential facade
t0 = time.perf_counter()
sequential = submit_many(reqs)
t_seq = time.perf_counter() - t0
for b, q in zip(batched, sequential):
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, w = getattr(b, f), getattr(q, f)
        assert (a is None) == (w is None)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(w))
print(f"parity: engine == sequential facade, bit-for-bit "
      f"(sequential pass took {t_seq * 1e3:.0f}ms)")

# contract 2: replaying the layouts hits the executable cache
misses = s.cache_misses
t0 = time.perf_counter()
eng.submit_batch(reqs)
t_warm = time.perf_counter() - t0
assert s.cache_misses == misses, "replay must not compile anything new"
assert s.cache_hits > 0 and s.hit_rate > 0
print(f"warm pass: {t_warm * 1e3:.0f}ms "
      f"({len(reqs) / max(t_warm, 1e-9):.0f} req/s); "
      f"cache {s.cache_hits} hits / {s.cache_misses} misses "
      f"(hit_rate={s.hit_rate:.2f})")
print("engine stats:", eng.stats.as_dict())

# contract 3: the async plane — open-loop arrivals with deadlines on a
# virtual clock; handles resolve on capacity/deadline flushes (or the
# final drain) and carry the same bits as the sequential facade
from repro.serve import AsyncTopologyEngine, VirtualClock  # noqa: E402

aeng = AsyncTopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch,
                           cache_capacity=cfg.cache_capacity,
                           slot_cost_cells=cfg.slot_cost_cells or None,
                           clock=VirtualClock())
handles = []
for req, (t_arr, deadline) in zip(trace.requests(), trace.arrivals):
    if t_arr > aeng.clock.now():
        aeng.advance(t_arr - aeng.clock.now())     # may deadline-flush
    handles.append(aeng.submit(req, deadline=deadline))
aeng.drain()
for h, q in zip(handles, sequential):
    assert h.done() and h.exception() is None
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, w = getattr(h.result(), f), getattr(q, f)
        assert (a is None) == (w is None)
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(w))
sa = aeng.stats
assert (sa.flush_capacity + sa.flush_deadline + sa.flush_drain
        + sa.flush_retry == sa.batches)
print(f"async plane: {len(handles)} handles resolved bit-identically; "
      f"flushes capacity={sa.flush_capacity} deadline={sa.flush_deadline} "
      f"drain={sa.flush_drain}; deadline_hit_rate={sa.deadline_hit_rate:.2f}; "
      f"virtual latency mean={sa.latency_mean * 1e3:.1f}ms")
print("OK")
