"""DPC inside a GNN data pipeline (paper technique x assigned archs):

1. build a large synthetic graph, sample minibatches with the CSR fanout
   sampler (the minibatch_lg cell's pipeline);
2. label every sampled subgraph's connected components with DPC-CC
   (core.connected_components_graph) — the pipeline sanity metric;
3. train a GAT for a few steps on the samples.

  PYTHONPATH=src python examples/gnn_cc_pipeline.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.data import graphs
from repro.models import gnn
from repro.optim import adamw


def main():
    rng = np.random.default_rng(0)
    n, deg = 20_000, 12
    indptr, indices = graphs.random_csr(n, deg, seed=1)
    feats = rng.standard_normal((n, 32)).astype(np.float32)
    labels = rng.integers(0, 7, n)
    sampler = graphs.NeighborSampler(indptr, indices, fanouts=(5, 3), seed=2)

    cfg = gnn.GATConfig(d_in=32, n_classes=7, d_hidden=8, n_heads=4)
    params = gnn.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(3e-3)
    state = opt.init(params)

    @jax.jit
    def step(params, state, batch):
        (loss, aux), grads = jax.value_and_grad(gnn.loss_fn, has_aux=True)(
            params, batch, cfg)
        params, state, _ = opt.update(grads, state, params)
        return params, state, loss, aux["acc"]

    for i in range(10):
        b = graphs.sampled_batch(sampler, feats, labels, batch_nodes=128,
                                 step=i)
        # DPC-CC pipeline check: how fragmented is this sample?
        cc = graphs.component_labels(b)
        n_comp = len(np.unique(cc[cc >= 0]))
        gb = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
              for k, v in b.items()}
        params, state, loss, acc = step(params, state, gb)
        print(f"step {i}: sampled {int(b['node_mask'].sum())} nodes in "
              f"{n_comp} DPC components | loss {float(loss):.4f} "
              f"acc {float(acc):.3f}")
    print("OK")


if __name__ == "__main__":
    main()
