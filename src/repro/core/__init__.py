"""Core library: Distributed Path Compression (Will et al., CS.DC 2024)."""
from .ids import compute_order, inverse_permutation, flat_ids, compact_labels
from .pathcompress import (path_compress, path_compress_unrolled, jump,
                           is_converged)
from .steepest import (grid_steepest, grid_mask_argmax, graph_steepest,
                       graph_mask_argmax, neighbor_offsets, shift_fill)
from .ms_segmentation import (ms_segmentation, ms_segmentation_graph,
                              descending_manifold, ascending_manifold,
                              extrema, MSSegmentation)
from .connected_components import (connected_components_grid,
                                   connected_components_graph,
                                   component_sizes, CCResult)
from .baseline_cc import label_propagation_grid, extract_masked_edges
from .distributed import (distributed_manifold,
                          distributed_connected_components,
                          make_dpc_mesh, BlockDecomp, DPCStats, AXIS,
                          BLOCK_AXES)
from .distributed_graph import (distributed_connected_components_graph,
                                GraphDecomp, GraphDPCStats)

__all__ = [
    "compute_order", "inverse_permutation", "flat_ids", "compact_labels",
    "path_compress", "path_compress_unrolled", "jump", "is_converged",
    "grid_steepest", "grid_mask_argmax", "graph_steepest", "graph_mask_argmax",
    "neighbor_offsets", "shift_fill",
    "ms_segmentation", "ms_segmentation_graph", "descending_manifold",
    "ascending_manifold", "extrema", "MSSegmentation",
    "connected_components_grid", "connected_components_graph",
    "component_sizes", "CCResult",
    "label_propagation_grid", "extract_masked_edges",
    "distributed_manifold", "distributed_connected_components",
    "make_dpc_mesh", "BlockDecomp", "DPCStats", "AXIS", "BLOCK_AXES",
    "distributed_connected_components_graph", "GraphDecomp", "GraphDPCStats",
]
