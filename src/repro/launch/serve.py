"""Batched-serving launcher.

Two serving modes share this entry point:

  # LM prefill + decode loop with a KV cache (original mode)
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16

  # Batched multi-tenant topology queries (DESIGN.md §Serve)
  PYTHONPATH=src python -m repro.launch.serve --topology --smoke \
      --requests 24 --repeat 2
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.launch.mesh import make_smoke_mesh
from repro.runtime.meshctx import use_mesh


def serve_lm(args):
    mod = configs.get(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg),
                     donate_argnums=1)

    with use_mesh(make_smoke_mesh()):
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out = [jnp.argmax(logits, -1)[:, None]]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, out[-1])
            out.append(jnp.argmax(logits, -1)[:, None])
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    toks = np.asarray(jnp.concatenate(out, axis=1))
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1e3:.1f}ms; decode {args.gen - 1} steps at "
          f"{tps:.1f} tok/s (incl. compile)")
    print("[serve] sample continuation ids:", toks[0][:12])
    assert np.isfinite(np.asarray(logits)).all()
    return tps


def serve_topology(args):
    """Drive the batched topology engine over a synthetic mixed workload.

    `--repeat` replays the same request sequence (same layouts, so the same
    bucket occupancies), and the second pass is served entirely from the
    executable cache — the printed hit rate is the number to watch on
    repeated-layout traffic.
    """
    from repro.serve import TopologyEngine
    from repro.serve.workload import synthetic_requests

    mod = configs.get("serve_topology")
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    eng = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch)

    t_total = 0.0
    n_total = 0
    for rep in range(args.repeat):
        reqs = synthetic_requests(
            args.requests, cfg.shapes, mix=cfg.mix,
            connectivity=cfg.connectivity, sweep_k=cfg.sweep_k,
            seed=args.seed)
        t0 = time.perf_counter()
        results = eng.submit_batch(reqs)
        dt = time.perf_counter() - t0
        t_total += dt
        n_total += len(results)
        info = eng.stats.as_dict()
        print(f"[serve-topology] pass {rep}: {len(results)} requests in "
              f"{dt * 1e3:.1f}ms ({len(results) / max(dt, 1e-9):.1f} req/s); "
              f"cumulative hit_rate={info['hit_rate']:.2f} "
              f"pad_fraction={info['pad_fraction']:.2f}")
    print("[serve-topology] engine stats:",
          json.dumps(eng.stats.as_dict(), sort_keys=True))
    return n_total / max(t_total, 1e-9)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", action="store_true",
                    help="serve batched CC/MS topology queries instead of LM")
    ap.add_argument("--requests", type=int, default=24,
                    help="topology mode: requests per pass")
    ap.add_argument("--repeat", type=int, default=2,
                    help="topology mode: workload passes (2nd hits the "
                         "executable cache)")
    args = ap.parse_args(argv)
    if args.topology:
        return serve_topology(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
