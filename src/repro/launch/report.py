"""Generate EXPERIMENTS.md tables from experiments/ artifacts."""
from __future__ import annotations

import json
import os
import sys


def dryrun_table(root="experiments/dryrun"):
    rows = []
    for mesh in ("pod256", "pod2x256"):
        d = os.path.join(root, mesh)
        if not os.path.isdir(d):
            continue
        for f in sorted(os.listdir(d)):
            with open(os.path.join(d, f)) as fh:
                r = json.load(fh)
            rows.append(r)
    by_cell = {}
    for r in rows:
        by_cell.setdefault(r["cell"], {})[r["mesh"]] = r
    out = ["| cell | mesh | state+temp GiB/dev | HLO GFLOP/dev | "
           "coll MiB/dev | #coll | compile s |",
           "|---|---|---|---|---|---|---|"]
    for cell in sorted(by_cell):
        for mesh in ("pod256", "pod2x256"):
            r = by_cell[cell].get(mesh)
            if not r:
                continue
            m = r["memory"]
            gib = (m["argument_size_in_bytes"] + m["temp_size_in_bytes"]) \
                / 2**30
            out.append(
                f"| {cell} | {mesh} | {gib:.2f} | "
                f"{r['cost'].get('flops', 0) / 1e9:.1f} | "
                f"{r['collectives']['total'] / 2**20:.0f} | "
                f"{r['collectives']['n_collectives']} | "
                f"{r['compile_s']:.0f} |")
    return "\n".join(out)


if __name__ == "__main__":
    what = sys.argv[1] if len(sys.argv) > 1 else "dryrun"
    if what == "dryrun":
        print(dryrun_table())
