from .perlin import perlin_noise
from .graphs import grid_edge_list

__all__ = ["perlin_noise", "grid_edge_list"]
