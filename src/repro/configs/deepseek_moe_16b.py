"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (GQA kv=16) d_ff=1408
vocab=102400, MoE 64e top-6 — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf].  All layers are MoE (the HF model's dense first
layer is folded into the shared experts — DESIGN.md §Arch-applicability)."""
import jax.numpy as jnp

from repro.models.lm import LMConfig, MoEConfig
from .lm_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b", n_layers=28, d_model=2048, n_heads=16,
        n_kv_heads=16, d_ff=1408, vocab=102400,
        moe=MoEConfig(n_experts=64, top_k=6, n_shared=2),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="deepseek-moe-16b-smoke", n_layers=2, d_model=64, n_heads=16,
        n_kv_heads=16, d_ff=32, vocab=128, d_head=4,
        moe=MoEConfig(n_experts=16, top_k=2, n_shared=2), loss_chunks=2)
