"""Minimal functional NN substrate (no flax): params are plain pytrees of
arrays; every layer is an (init, apply) pair of pure functions."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

Param = Any  # a pytree of jnp arrays


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.truncated_normal(key, -2, 2, (in_dim, out_dim),
                                        jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32):
    return (jax.random.truncated_normal(key, -2, 2, (vocab, dim),
                                        jnp.float32)).astype(dtype)


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w.astype(jnp.float32)).astype(dt)


def rope(x, positions, theta: float = 500_000.0):
    """Rotary embedding.  x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def cross_entropy_chunked(h, unembed, labels, n_chunks: int = 8,
                          logit_dtype=jnp.float32):
    """Token-mean cross entropy without materialising (B, S, V) at once:
    scan over sequence chunks — the (chunk, V) logits live only inside one
    scan step (with remat this bounds the train-step live set by V/chunks).

    h: (B, S, D); unembed: (D, V); labels: (B, S) int; label<0 = padding.
    """
    b, s, dm = h.shape
    if s % n_chunks:
        n_chunks = 1
    cs = s // n_chunks
    hc = h.reshape(b, n_chunks, cs, dm).swapaxes(0, 1)      # (n, B, cs, D)
    lc = labels.reshape(b, n_chunks, cs).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        hh, ll = xs
        logits = (hh.astype(logit_dtype) @ unembed.astype(logit_dtype))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.clip(ll, 0)[..., None], axis=-1)[..., 0]
        valid = ll >= 0
        nll = jnp.where(valid, lse - gold, 0.0)
        return (tot + nll.sum(), cnt + valid.sum()), None

    (tot, cnt), _ = lax.scan(body, (jnp.float32(0.0), jnp.int32(0)), (hc, lc))
    return tot / jnp.maximum(cnt, 1)
