"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352  [hf:stabilityai/stablelm-2-12b; hf]"""
import jax.numpy as jnp

from repro.models.lm import LMConfig
from .lm_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name="stablelm-12b", n_layers=40, d_model=5120, n_heads=32,
        n_kv_heads=8, d_ff=13824, vocab=100352,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="stablelm-12b-smoke", n_layers=2, d_model=64, n_heads=16,
        n_kv_heads=4, d_ff=160, vocab=128, d_head=4, loss_chunks=2)
