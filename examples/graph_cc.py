"""Distributed unstructured CC: the paper's graph path in 60 seconds.

Builds a synthetic tet-mesh-style edge list (the Freudenthal
tetrahedralization of a Perlin-noise grid, treated as a fully unstructured
mesh), labels the thresholded connected components on one device, then
vertex-partitions the mesh over every local device with GraphDecomp and
checks the distributed labels are bit-identical — with exactly one
all_gather communication phase (paper Alg. 2's budget).

  PYTHONPATH=src python examples/graph_cc.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import make_dpc_mesh
from repro.core.connected_components import connected_components_graph
from repro.core.distributed_graph import (
    GraphDecomp, distributed_connected_components_graph)
from repro.data import perlin_noise, grid_edge_list


def main():
    # --- the mesh: a tet-mesh-style edge list over a Perlin field ----------
    shape = (16, 16, 16)
    n = int(np.prod(shape))
    senders, receivers = grid_edge_list(shape, connectivity=14)
    field = perlin_noise(shape, frequency=0.1, seed=42)
    mask = jnp.asarray((field > np.quantile(field, 0.9)).ravel())
    print(f"tet-style mesh: {n} vertices, {senders.size} directed edges, "
          f"{int(mask.sum())} masked (top 10%)")

    # --- single-device oracle (paper Alg. 3 on graphs) ---------------------
    ref = connected_components_graph(mask, jnp.asarray(senders),
                                     jnp.asarray(receivers))
    labels = np.asarray(ref.labels)
    n_comp = len(np.unique(labels[labels >= 0]))
    print(f"single device: {n_comp} components "
          f"({int(ref.n_rounds)} stitch rounds)")

    # --- distributed: vertex partition over all local devices --------------
    n_dev = len(jax.devices())
    nparts = max(d for d in range(1, n_dev + 1) if n % d == 0)
    dec = GraphDecomp(n, senders, receivers, nparts)
    mesh = make_dpc_mesh(nparts)
    got, stats = distributed_connected_components_graph(mask, dec, mesh)
    assert (np.asarray(got) == labels).all(), "labels must be bit-identical"
    print(f"distributed over {nparts} partition(s): identical labels; "
          f"{int(stats.comm_phases)} all_gather phase, "
          f"{int(stats.ghost_bytes):,} cut-table bytes, "
          f"{int(stats.table_iters)} table rounds")

    # --- pure geometry (no scalar data): mask = ones -----------------------
    ones = jnp.ones(n, bool)
    g_ref = connected_components_graph(ones, jnp.asarray(senders),
                                       jnp.asarray(receivers))
    g_got, _ = distributed_connected_components_graph(ones, dec, mesh)
    assert (np.asarray(g_got) == np.asarray(g_ref.labels)).all()
    print("pure-geometry CC (mask=ones): identical labels")


if __name__ == "__main__":
    main()
