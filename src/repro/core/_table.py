"""Shared machinery for the boundary-table phase (paper Alg. 2).

Both distributed backends — the N-D block decomposition of structured grids
(`distributed.py`) and the vertex partition of unstructured edge-list meshes
(`distributed_graph.py`) — end their local phase by resolving cross-shard
segments on a flat table of boundary/cut labels.  Two table layouts exist
(deviation (s) in DESIGN.md):

  * **replicated** (deviation (b)): ONE all_gather replicates every owned
    boundary slot on every device; the table is post-processed identically
    everywhere.
  * **sharded**: each device materializes only its OWN slots plus a one-hop
    halo of neighbor slots (a "stack"), and the cross-shard fixpoint runs as
    outer rounds of [halo exchange -> local resolve -> global changed?] —
    see `sharded_fixpoint` below.

The post-processing is backend- and layout-agnostic once two lookups are
fixed:

  * how a *label value* maps to its slot in the device's view (coordinate
    arithmetic for blocks, a sorted-gid search for graphs) — a `lookup`
    closure, bundled with the slot values as a `TableView`;
  * which slots are adjacent across shard cuts — a `cut_max` closure.

This module holds the backend-independent pieces: the pointer-doubling chase
(Alg. 2 lines 15-25), the equal-label group machinery and hook+propagate
fixpoint of deviation (d2) in DESIGN.md, the value-search substitution
(Alg. 2 lines 27-33 generalised to merged labels), and the sharded outer
exchange driver.

Sentinel contract (deviation (p) in DESIGN.md): ragged decompositions pad
their tables with slots whose label is -1 and whose mask is False.
Everything here is sentinel-aware by construction — `pointer_chase` fixes
entries < 0 (the backend `lookup` closures gate on `t >= 0`), the cut hooks
fed to `hook_propagate` gate on the mask (False at padding, so a pad slot
can never hook or be hooked), and `value_substitute` leaves negative labels
untouched — so pad slots can never leak a label into a real component, nor
acquire one.  The sharded halo reuses the same sentinels for lattice-edge
fill chunks.
"""
from __future__ import annotations

from typing import Callable, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax


TABLE_MODES = ("replicated", "sharded")


def check_table_mode(table_mode: str) -> None:
    if table_mode not in TABLE_MODES:
        raise ValueError(
            f"table_mode must be one of {TABLE_MODES}, got {table_mode!r}")


class TableView(NamedTuple):
    """One device's view of the boundary/cut table.

    `values` are the flat label slots this device materializes — the FULL
    gathered table in replicated mode, own slots followed by the one-hop
    halo stack in sharded mode (the own chunk is ALWAYS `values[..., :n_own]`
    along the last axis; batched entry points carry leading dims).
    `lookup(t)` maps label values through the view: value -> slot in this
    view -> entry at that slot, identity where the value has no slot here
    (non-boundary targets, unresolvable `< 0` entries, out-of-view slots in
    sharded mode).
    """
    values: jax.Array
    lookup: Callable
    n_own: int


def pointer_chase(T, lookup, max_iter: int = 64):
    """Pointer doubling on a flat table (Alg. 2 lines 15-25).

    `lookup(t)` maps every entry of the current table `t` through the table
    itself (entry value -> slot -> entry at that slot), leaving unresolvable
    entries (unmasked `< 0`, non-boundary targets) fixed.  Iterates to the
    fixpoint; returns (compressed table, rounds executed, converged).
    `converged` is False when the loop was cut off at `max_iter` with the
    last round still changing entries — the result may then be mid-chain.
    """
    def cond(s):
        _, ch, i = s
        return ch & (i < max_iter)

    def body(s):
        t, _, i = s
        nt = lookup(t)
        return nt, jnp.any(nt != t), i + jnp.int32(1)

    T, ch, iters = lax.while_loop(cond, body,
                                  (T, jnp.asarray(True), jnp.int32(0)))
    return T, iters, ~ch


def chase_view(view: TableView, max_iter: int = 64):
    """`pointer_chase` over a `TableView`; returns (view', iters, converged)."""
    T, iters, ok = pointer_chase(view.values, view.lookup, max_iter)
    return view._replace(values=T), iters, ok


def make_group_max(Tstar):
    """Equal-label group structure of a (compressed) table.

    Slots sharing a label belong to the same (partial) component; groups are
    realised as runs of the sorted table so a group reduction is one
    `segment_max` (sorted-runs trick, no hash table).  Returns
    (group_max fn, perm, sorted_vals); the latter two also drive the final
    value-search substitution.
    """
    msize = Tstar.size
    perm = jnp.argsort(Tstar)
    sorted_vals = Tstar[perm]
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_vals[1:] != sorted_vals[:-1]])
    run_id = jnp.cumsum(run_start) - 1
    inv_perm = jnp.zeros(msize, dtype=jnp.int32).at[perm].set(
        jnp.arange(msize, dtype=jnp.int32))

    def group_max(L):
        gm = jax.ops.segment_max(L[perm], run_id, num_segments=msize)
        return gm[run_id][inv_perm]

    return group_max, perm, sorted_vals


def hook_propagate(Tstar, cut_max, group_max, max_iter: int = 64):
    """Hook + propagate fixpoint on the compressed table (deviation (d2) in
    DESIGN.md): alternate `cut_max` (max across masked cut edges between
    table slots) and `group_max` (max within equal-original-label groups)
    until no label changes.  Computes, per slot, the largest label of its
    *global* component.  The paper compresses the ghost table with path
    compression only; that cannot *merge* components whose local roots are
    interior vertices — this fixpoint can, and stays within the paper's
    single-communication-phase budget (it only post-processes the
    already-gathered table).  Returns (labels, rounds, converged);
    `converged` is False when cut off at `max_iter` mid-flood.
    """
    def cond(st):
        _, ch, i = st
        return ch & (i < max_iter)

    def body(st):
        L, _, i = st
        nxt = group_max(cut_max(L))
        return nxt, jnp.any(nxt != L), i + jnp.int32(1)

    L, ch, iters = lax.while_loop(
        cond, body, (Tstar, jnp.asarray(True), jnp.int32(0)))
    return L, iters, ~ch


def value_substitute(o, chased, sorted_vals, g_sorted):
    """Final substitution for CC (Alg. 2 lines 27-33 generalised): take each
    owned label `chased` through the table, then adopt its equal-label
    group's propagated maximum, found by *value* (searchsorted over the
    sorted table) — by value because an owned label can name an interior
    root that is not itself a table slot but shares its value with cut
    vertices of the same local piece.  `o` is the pre-chase label; `< 0`
    (unmasked) entries stay -1.
    """
    idx = jnp.clip(jnp.searchsorted(sorted_vals, chased),
                   0, sorted_vals.shape[0] - 1)
    found = sorted_vals[idx] == chased
    improved = jnp.where(found & (chased >= 0),
                         jnp.maximum(g_sorted[idx], chased), chased)
    return jnp.where(o < 0, -1, improved)


def sharded_fixpoint(own0, exchange, refine, reduce_any, max_rounds: int = 64):
    """Outer halo-exchange driver of the sharded table mode (deviation (s)).

    `own0` is the device's owned slot chunk (last axis = slots; batched
    callers carry leading dims).  `exchange(own) -> stack` rebuilds the
    own+halo view from fresh owned values (the own chunk MUST land at
    `stack[..., :n_own]`); `refine(stack) -> (stack', iters, ok)` resolves
    the view locally (pointer-doubling chase or hook+propagate — both
    saturate *within* the view, so a round relays information one halo hop
    while compressing arbitrarily long in-view segments); `reduce_any`
    reduces a per-device "changed" flag across the mesh (lax.pmax over the
    decomposed axes).  Rounds repeat until no device's owned chunk changes:
    because every refine step only copies/maxes labels monotonically along
    the same chain/component structure the replicated table resolves, the
    unique global fixpoint — and hence the final labels — is bit-identical
    to the replicated mode (DESIGN.md §Table-sharding).

    Returns (stack, own, exchange_rounds, total inner iters, converged).
    The returned stack holds the converged owned chunk plus a FRESH halo of
    the neighbors' converged values (the trailing exchange is counted in
    `exchange_rounds`), so value lookups for the final substitution can read
    it directly.
    """
    n_own = own0.shape[-1]

    def cond(st):
        _, _, ch, r, _, _ = st
        return ch & (r < max_rounds)

    def body(st):
        stack, own, _, r, it, ok = st
        stack2, inner, ok2 = refine(stack)
        new_own = stack2[..., :n_own]
        ch = reduce_any(jnp.any(new_own != own))
        return (exchange(new_own), new_own, ch, r + jnp.int32(1),
                it + inner, ok & ok2)

    init = (exchange(own0), own0, jnp.asarray(True), jnp.int32(1),
            jnp.int32(0), jnp.asarray(True))
    stack, own, ch, rounds, iters, ok = lax.while_loop(cond, body, init)
    return stack, own, rounds, iters, ok & ~ch


def check_converged(flag, what: str, max_iter: int) -> None:
    """Raise eagerly when a table fixpoint was cut off at `max_iter` instead
    of returning a silently-wrong answer (the pre-PR-9 failure mode).

    Under tracing (jit / vmap of the public entry points) the flag is
    abstract and the check is skipped — callers must then consult the
    `converged` stats field themselves.
    """
    try:
        ok = bool(np.all(np.asarray(flag)))
    except jax.errors.TracerArrayConversionError:
        return
    if not ok:
        raise RuntimeError(
            f"{what}: table resolution did not reach its fixpoint within "
            f"max_iter={max_iter} rounds; labels would be mid-chain/"
            f"mid-flood. Raise `table_max_iter` (the stats field "
            f"`converged` carries the same flag under jit).")
