"""schnet [gnn]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10
[arXiv:1706.08566; paper]"""
from repro.models.gnn import SchNetConfig
from .gnn_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "gnn"


def full_config() -> SchNetConfig:
    return SchNetConfig(name="schnet", n_interactions=3, d_hidden=64,
                        n_rbf=300, cutoff=10.0)


def smoke_config() -> SchNetConfig:
    return SchNetConfig(name="schnet-smoke", n_interactions=2, d_hidden=16,
                        n_rbf=16, cutoff=10.0)
