"""DPCStats invariants under the block decomposition (fast CI job).

* ghost_bytes equals the closed-form total boundary *surface* of the block
  lattice — it scales with surface, not volume, when the grid grows; under
  ragged (non-divisible) extents only in-domain face cells count — padded
  cells must not (deviation (p) in DESIGN.md);
* comm_phases == 1: padding must not add exchange phases;
* table_iters is bit-identical on every device (all devices compress the
  same gathered table — the replicated-table invariant the substitution
  step relies on).
"""
import os
import subprocess
import sys
import textwrap

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import math
    import numpy as np
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import make_dpc_mesh, distributed_manifold, \\
        distributed_connected_components, compute_order
    from repro.core.distributed import _manifold_block, _cc_block, _decomp_for
    from repro.core._shardmap import shard_map_norep

    assert len(jax.devices()) == 8

    def surface_bytes(grid, layout, itemsize=4):
        # independent reimplementation: only in-domain face cells count —
        # along axis a a block's lo/hi face position is in-domain iff its
        # coordinate is < grid[a]; each in-domain face position carries
        # prod(grid[i != a]) in-domain cells (deviation (p) in DESIGN.md).
        # For divisible grids this reduces to the old nb*2*face_size form.
        k = len(layout)
        local = [-(-g // p) for g, p in zip(grid, layout)]
        total = math.prod(grid)
        n = 0
        for a in range(k):
            f = sum(int(b * local[a] < grid[a])
                    + int(b * local[a] + local[a] - 1 < grid[a])
                    for b in range(layout[a]))
            n += f * (total // grid[a])
        return n * itemsize

    rng = np.random.default_rng(0)

    # --- ghost_bytes == closed-form boundary surface (divisible + ragged;
    #     ragged cases include an entirely-padded trailing block) ----------
    for grid, layout in [((8, 8, 8), (8,)), ((8, 8, 8), (2, 4)),
                         ((8, 8, 8), (2, 2, 2)), ((8, 12, 6), (4, 2)),
                         ((17, 13, 11), (2, 2, 2)), ((7, 9), (2, 2)),
                         ((5, 7), (4,)), ((13, 11, 7), (2, 4))]:
        order = compute_order(jnp.asarray(rng.standard_normal(grid)))
        _, st = distributed_manifold(order, make_dpc_mesh(layout), 6)
        assert int(st.ghost_bytes) == surface_bytes(grid, layout), \\
            (grid, layout, int(st.ghost_bytes))
        assert int(st.comm_phases) == 1, (grid, layout)
        ragged = any(g % p for g, p in zip(grid, layout))
        assert (float(st.pad_fraction) > 0) == ragged, (grid, layout)
        mask = jnp.asarray(rng.random(grid) < 0.5)
        _, st = distributed_connected_components(
            mask, make_dpc_mesh(layout), 6, gather_mask=True)
        # labels (4B) + gathered mask (1B) per in-domain boundary slot
        assert int(st.ghost_bytes) == surface_bytes(grid, layout, 5), \\
            (grid, layout, int(st.ghost_bytes))
        assert int(st.comm_phases) == 1, (grid, layout)
        assert 0.0 <= float(st.masked_ghost_fraction) <= 1.0, (grid, layout)

    # --- surface (not volume) scaling under grid growth -------------------
    gb = {}
    for grid in [(8, 8, 8), (16, 16, 16)]:
        order = compute_order(jnp.asarray(rng.standard_normal(grid)))
        _, st = distributed_manifold(order, make_dpc_mesh((2, 2, 2)), 6)
        gb[grid] = int(st.ghost_bytes)
    # volume grew 8x; boundary surface (and the ONE comm phase) only 4x
    assert gb[(16, 16, 16)] == 4 * gb[(8, 8, 8)], gb

    # blocks beat slabs at equal device count (surface-to-volume)
    order = compute_order(jnp.asarray(rng.standard_normal((8, 8, 8))))
    _, st_slab = distributed_manifold(order, make_dpc_mesh((8,)), 6)
    _, st_blk = distributed_manifold(order, make_dpc_mesh((2, 2, 2)), 6)
    assert int(st_blk.ghost_bytes) < int(st_slab.ghost_bytes)

    # --- table_iters identical across devices -----------------------------
    grid = (8, 8, 6)
    order = compute_order(jnp.asarray(rng.standard_normal(grid)))
    mask = jnp.asarray(rng.random(grid) < 0.6)
    for layout in [(4, 2), (2, 2, 2)]:
        mesh = make_dpc_mesh(layout)
        dec = _decomp_for(mesh, grid)
        one = (1,) * len(layout)
        spec = P(*dec.names, *([None] * (len(grid) - dec.k)))
        tspec = P(*dec.names)

        def man(blk):
            labels, st = _manifold_block(blk, dec=dec, connectivity=6)
            return labels, st.table_iters.reshape(one)

        def cc(blk):
            labels, st = _cc_block(blk, dec=dec, connectivity=6)
            return labels, st.table_iters.reshape(one)

        for fn, arg in ((man, order), (cc, mask)):
            _, ti = shard_map_norep(fn, mesh, (spec,),
                                    (spec, tspec))(arg)
            ti = np.asarray(ti).ravel()
            assert (ti == ti[0]).all(), (layout, fn.__name__, ti)

    print("STATS-OK")
""")


def test_dpc_stats_invariants():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "STATS-OK" in proc.stdout
