"""meshgraphnet [gnn]: n_layers=15 d_hidden=128 aggregator=sum mlp_layers=2
[arXiv:2010.03409; unverified]"""
from repro.models.gnn import MGNConfig
from .gnn_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "gnn"


def full_config() -> MGNConfig:
    return MGNConfig(name="meshgraphnet", n_layers=15, d_hidden=128,
                     mlp_layers=2)


def smoke_config() -> MGNConfig:
    return MGNConfig(name="meshgraphnet-smoke", n_layers=3, d_hidden=16,
                     mlp_layers=2)
