"""Batched multi-tenant topology serving (DESIGN.md §Serve / §Serve-v2 /
§Serve-v3).

    from repro.serve import TopologyEngine
    from repro.topology import TopologyRequest

    eng = TopologyEngine()
    results = eng.submit_batch([TopologyRequest("cc", mask=m), ...])
    eng.stats.as_dict()   # requests/batches, cache hit rate, pad waste

Async plane (queueing, deadline-aware flushing, split-retry, idempotency):

    from repro.serve import AsyncTopologyEngine, VirtualClock

    eng = AsyncTopologyEngine(clock=VirtualClock())
    h = eng.submit(req, deadline=0.5, idempotency_key="tenant-42/9001")
    eng.advance(0.5)      # deadline flush (virtual time)
    h.result()            # bit-identical to repro.topology.submit(req)

Overload plane (admission control, load shedding, shared compiles):

    from repro.serve import SharedExecutableCache, PlaneError

    cache = SharedExecutableCache(capacity=64)
    eng = AsyncTopologyEngine(max_queue_depth=256, shed_policy="hopeless",
                              compile_cache=cache, name="replica-0")
    h = eng.submit(req, deadline=...)
    if h.done() and isinstance(h.exception(), PlaneError):
        ...               # Overloaded (rejected) or DeadlineShed (dropped)
"""
from .engine import (TopologyEngine, AsyncTopologyEngine, TopologyHandle,
                     EngineStats, PlaneError, Overloaded, DeadlineShed)
from .compile_cache import SharedExecutableCache
from .scheduler import (FlushScheduler, VirtualClock, MonotonicClock,
                        COLD_START_ESTIMATE, SHED_POLICIES)
from .bucketing import (bucket_shape, batch_capacity, remap_flat_labels,
                        merge_adjacent_layouts)

__all__ = ["TopologyEngine", "AsyncTopologyEngine", "TopologyHandle",
           "EngineStats", "PlaneError", "Overloaded", "DeadlineShed",
           "SharedExecutableCache", "FlushScheduler", "VirtualClock",
           "MonotonicClock", "COLD_START_ESTIMATE", "SHED_POLICIES",
           "bucket_shape", "batch_capacity", "remap_flat_labels",
           "merge_adjacent_layouts"]
