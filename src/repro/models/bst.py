"""Behavior Sequence Transformer (Chen et al., arXiv:1905.06874).

Huge sparse embedding tables -> transformer over the user behavior sequence
(+ target item) -> MLP head.  JAX has no native EmbeddingBag: multi-hot
profile fields use jnp.take + jax.ops.segment_sum (the assignment's required
construction).  The item table is row-sharded on "tp"; the lookup is the
hot path (see §Roofline).

retrieval_cand: the pooled user vector scores 1M candidate item embeddings
with one batched dot + lax.top_k (no loop)."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.nn.core import dense_init, embed_init, rms_norm
from repro.kernels.ref import mha_ref
from repro.runtime.meshctx import constrain


@dataclasses.dataclass(frozen=True)
class BSTConfig:
    name: str = "bst"
    embed_dim: int = 32
    seq_len: int = 20
    n_blocks: int = 1
    n_heads: int = 8
    mlp_dims: tuple = (1024, 512, 256)
    item_vocab: int = 4_194_304        # 2**22 rows — the huge sparse table
    n_profile_fields: int = 8          # single-hot categorical fields
    profile_vocab: int = 100_000
    n_multihot_fields: int = 2         # EmbeddingBag fields
    multihot_vocab: int = 500_000
    multihot_len: int = 16             # ids per bag (padded, -1 = empty)
    d_ff: int = 128
    param_dtype: Any = jnp.float32


def init_params(key, cfg: BSTConfig):
    ks = jax.random.split(key, 12 + 4 * cfg.n_blocks)
    d = cfg.embed_dim
    dt = cfg.param_dtype
    blocks = []
    for i in range(cfg.n_blocks):
        k1, k2, k3, k4 = jax.random.split(ks[12 + i], 4)
        blocks.append({
            "wqkv": dense_init(k1, d, 3 * d, dt),
            "wo": dense_init(k2, d, d, dt),
            "w1": dense_init(k3, d, cfg.d_ff, dt),
            "w2": dense_init(k4, cfg.d_ff, d, dt),
            "ln1": jnp.ones((d,), dt), "ln2": jnp.ones((d,), dt),
        })
    seq_total = cfg.seq_len + 1
    mlp_in = seq_total * d + cfg.n_profile_fields * d \
        + cfg.n_multihot_fields * d
    mlp = []
    dims = (mlp_in,) + cfg.mlp_dims + (1,)
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        mlp.append({"w": dense_init(ks[4 + i % 8], a, b, dt),
                    "b": jnp.zeros((b,), dt)})
    return {
        "item_embed": embed_init(ks[0], cfg.item_vocab, d, dt) * 0.02,
        "pos_embed": embed_init(ks[1], seq_total, d, dt) * 0.02,
        "profile_embed": embed_init(
            ks[2], cfg.n_profile_fields * cfg.profile_vocab, d, dt) * 0.02,
        "multihot_embed": embed_init(
            ks[3], cfg.n_multihot_fields * cfg.multihot_vocab, d, dt) * 0.02,
        "blocks": blocks,
        "mlp": mlp,
    }


def param_logical_specs(cfg: BSTConfig):
    block = {"wqkv": (None, None), "wo": (None, None),
             "w1": (None, None), "w2": (None, None),
             "ln1": (None,), "ln2": (None,)}
    return {
        "item_embed": ("tp", None),       # row-sharded huge table
        "pos_embed": (None, None),
        "profile_embed": ("tp", None),
        "multihot_embed": ("tp", None),
        "blocks": [block] * cfg.n_blocks,
        "mlp": [{"w": ("fsdp", "tp"), "b": (None,)},
                ] + [{"w": (None, None), "b": (None,)}] * len(cfg.mlp_dims),
    }


def embedding_bag(table, ids, mode: str = "sum"):
    """EmbeddingBag via gather + segment-reduce.  ids: (B, L) with -1 pads.
    Returns (B, D)."""
    b, l = ids.shape
    flat = ids.reshape(-1)
    valid = flat >= 0
    rows = jnp.take(table, jnp.clip(flat, 0), axis=0)
    rows = jnp.where(valid[:, None], rows, 0.0)
    seg = jnp.repeat(jnp.arange(b), l)
    out = jax.ops.segment_sum(rows, seg, num_segments=b)
    if mode == "mean":
        cnt = jax.ops.segment_sum(valid.astype(out.dtype), seg,
                                  num_segments=b)
        out = out / jnp.maximum(cnt, 1.0)[:, None]
    return out


def _transformer_pool(params, seq_emb, cfg: BSTConfig):
    """seq_emb: (B, S+1, D) -> same shape after n_blocks of post-LN MHA+FFN
    (BST uses one block)."""
    b, s, d = seq_emb.shape
    h = cfg.n_heads
    dh = d // h
    x = seq_emb
    for blk in params["blocks"]:
        qkv = x @ blk["wqkv"]
        q, k, v = jnp.split(qkv, 3, axis=-1)
        q = q.reshape(b, s, h, dh).swapaxes(1, 2)
        k = k.reshape(b, s, h, dh).swapaxes(1, 2)
        v = v.reshape(b, s, h, dh).swapaxes(1, 2)
        o = mha_ref(q, k, v, causal=False)
        o = o.swapaxes(1, 2).reshape(b, s, d) @ blk["wo"]
        x = rms_norm(x + o, blk["ln1"])
        f = jax.nn.relu(x @ blk["w1"]) @ blk["w2"]
        x = rms_norm(x + f, blk["ln2"])
    return x


def user_tower(params, batch, cfg: BSTConfig):
    """Everything except the final MLP: returns (seq_repr (B, (S+1)*D),
    profile_repr (B, F*D))."""
    hist = batch["hist_items"]          # (B, S) item ids
    target = batch["target_item"]       # (B,)
    seq_ids = jnp.concatenate([hist, target[:, None]], axis=1)
    seq = jnp.take(params["item_embed"], seq_ids, axis=0)
    seq = seq + params["pos_embed"][None, :, :]
    seq = constrain(seq, "dp", None, None)
    seq = _transformer_pool(params, seq, cfg)
    b = hist.shape[0]

    # single-hot profile fields: one fused gather over the concatenated table
    prof_ids = batch["profile_ids"] + (
        jnp.arange(cfg.n_profile_fields) * cfg.profile_vocab)[None, :]
    prof = jnp.take(params["profile_embed"], prof_ids, axis=0)  # (B, F, D)

    # multi-hot fields through the EmbeddingBag
    bags = []
    for f in range(cfg.n_multihot_fields):
        ids = batch["multihot_ids"][:, f]      # (B, L)
        ids = jnp.where(ids >= 0, ids + f * cfg.multihot_vocab, -1)
        bags.append(embedding_bag(params["multihot_embed"], ids))
    bag = jnp.stack(bags, axis=1)              # (B, F2, D)

    return (seq.reshape(b, -1), jnp.concatenate(
        [prof.reshape(b, -1), bag.reshape(b, -1)], axis=-1))


def forward(params, batch, cfg: BSTConfig):
    """CTR logits (B,)."""
    seq_r, prof_r = user_tower(params, batch, cfg)
    x = jnp.concatenate([seq_r, prof_r], axis=-1)
    x = constrain(x, "dp", None)
    for i, l in enumerate(params["mlp"]):
        x = x @ l["w"] + l["b"]
        if i < len(params["mlp"]) - 1:
            x = jax.nn.leaky_relu(x, 0.1)
    return x[:, 0]


def loss_fn(params, batch, cfg: BSTConfig):
    logits = forward(params, batch, cfg)
    y = batch["labels"].astype(jnp.float32)
    loss = jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))
    auc_proxy = jnp.mean((logits > 0) == (y > 0.5))
    return loss, {"acc": auc_proxy}


def retrieval_step(params, batch, cfg: BSTConfig, top_k: int = 100):
    """Score one user against `n_candidates` items: pooled user vector from
    the behavior sequence, batched dot against candidate embeddings, top-k.
    batch["candidates"]: (B, N_cand) item ids."""
    seq_r, _ = user_tower(params, batch, cfg)
    b = seq_r.shape[0]
    d = cfg.embed_dim
    u = seq_r.reshape(b, cfg.seq_len + 1, d).mean(axis=1)   # (B, D)
    cand = jnp.take(params["item_embed"], batch["candidates"], axis=0)
    scores = jnp.einsum("bd,bnd->bn", u, cand)
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, jnp.take_along_axis(batch["candidates"], idx, axis=1)
