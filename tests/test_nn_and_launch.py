"""NN substrate + launch-layer units: chunked CE oracle, RoPE properties,
logical-axis translation, HLO collective parser, perlin determinism."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.nn.core import cross_entropy_chunked, rms_norm, rope
from repro.runtime.meshctx import logical_to_spec, use_mesh, constrain
from repro.launch.dryrun import collective_bytes, _shape_bytes
from repro.data import perlin_noise


def test_chunked_ce_matches_full():
    key = jax.random.PRNGKey(0)
    b, s, d, v = 2, 16, 8, 32
    h = jax.random.normal(key, (b, s, d))
    w = jax.random.normal(jax.random.PRNGKey(1), (d, v))
    labels = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, v)
    full = -jnp.take_along_axis(
        jax.nn.log_softmax(h @ w, -1), labels[..., None], -1).mean()
    for nc in (1, 2, 4, 8):
        got = cross_entropy_chunked(h, w, labels, n_chunks=nc)
        np.testing.assert_allclose(float(got), float(full), rtol=1e-5)


def test_chunked_ce_padding_labels():
    h = jnp.ones((1, 4, 8))
    w = jnp.zeros((8, 16))
    labels = jnp.array([[1, 2, -1, -1]])
    got = cross_entropy_chunked(h, w, labels, n_chunks=2)
    # uniform logits -> log(16); padded positions excluded
    np.testing.assert_allclose(float(got), np.log(16), rtol=1e-6)


def test_rms_norm_unit_scale():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 64)) * 10
    y = rms_norm(x, jnp.ones(64))
    rms = jnp.sqrt((y * y).mean(-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, 2, 16))
    pos = jnp.broadcast_to(jnp.arange(8), (1, 8))
    y = rope(x, pos)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(y, axis=-1)),
                               np.asarray(jnp.linalg.norm(x, axis=-1)),
                               rtol=1e-5)
    # dot(q_i, k_j) depends only on i - j
    q = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(3), (1, 1, 1, 16))
    def dot_at(i, j):
        qi = rope(jnp.broadcast_to(q, (1, 1, 1, 16)),
                  jnp.full((1, 1), i))
        kj = rope(jnp.broadcast_to(k, (1, 1, 1, 16)),
                  jnp.full((1, 1), j))
        return float(jnp.sum(qi * kj))
    np.testing.assert_allclose(dot_at(5, 3), dot_at(7, 5), rtol=1e-4)


def test_logical_to_spec_drops_missing_axes():
    mesh1 = jax.make_mesh((1,), ("data",))
    spec = logical_to_spec(("dp", "tp", None), mesh1)
    assert spec == jax.sharding.PartitionSpec("data", None, None)
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    spec = logical_to_spec(("dp", "sp", None), mesh2)
    assert spec == jax.sharding.PartitionSpec("data", "model", None)


def test_constrain_noop_without_mesh():
    x = jnp.ones((4, 4))
    y = constrain(x, "dp", None)
    np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_shape_bytes_parser():
    assert _shape_bytes("f32[128,256]") == 128 * 256 * 4
    assert _shape_bytes("bf16[2,4]{1,0}") == 16
    assert _shape_bytes("(f32[8], s32[4])") == 32 + 16
    assert _shape_bytes("pred[16]") == 16


def test_collective_parser():
    hlo = """
  %ag = bf16[512,1024]{1,0} all-gather(bf16[32,1024] %x), dimensions={0}
  %ar.1 = f32[256]{0} all-reduce(f32[256] %y), to_apply=%add
  %cp = f32[2,8]{1,0} collective-permute(f32[2,8] %z), source_target_pairs={{0,1}}
  %nothing = f32[4] add(f32[4] %a, f32[4] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 512 * 1024 * 2
    assert out["all-reduce"] == 256 * 4
    assert out["collective-permute"] == 2 * 8 * 4
    assert out["n_collectives"] == 3
    assert out["total"] == out["all-gather"] + out["all-reduce"] + \
        out["collective-permute"]


def test_perlin_shard_consistency():
    """Shards regenerating their own slab get bit-identical values — the
    weak-scaling data path never materialises the global grid."""
    full = perlin_noise((32, 16, 8), frequency=0.1, seed=0)
    slab = perlin_noise((8, 16, 8), frequency=0.1, seed=0, origin=(16, 0, 0))
    np.testing.assert_array_equal(full[16:24], slab)


def test_perlin_statistics():
    f = perlin_noise((64, 64), frequency=0.1, seed=1)
    assert abs(float(f.mean())) < 0.1
    assert 0.05 < float(f.std()) < 1.0
