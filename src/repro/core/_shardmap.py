"""shard_map compat: the API moved from `jax.experimental.shard_map` to
`jax.shard_map` and renamed `check_rep` to `check_vma` along the way.  All
SPMD entry points in this repo go through `shard_map_norep`, which disables
the replication check under whichever name the installed jax uses."""
from __future__ import annotations

import inspect

import jax

try:
    _shard_map = jax.shard_map
except AttributeError:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

_CHECK_KW = ("check_vma"
             if "check_vma" in inspect.signature(_shard_map).parameters
             else "check_rep")


def shard_map_norep(fn, mesh, in_specs, out_specs):
    return _shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **{_CHECK_KW: False})
