"""Pallas TPU kernel: steepest-neighbor stencil (DPC init, Alg. 1 l. 3-5).

The DPC hot spot on init is a 6/14-point argmax stencil over the order field.
TPU adaptation: tile the grid into x-slabs that fit VMEM; each tile is loaded
once together with two pre-sliced halo planes (avoids overlapping BlockSpecs),
and the argmax over the static offset list is fully vectorised on the VPU —
one HBM read + one HBM write per voxel instead of the scalar neighbor loop of
the CPU implementation.

Layout per grid step i (grid = X / block_x):
  center ref: (block_x, Y, Z)   <- order[i*block_x : (i+1)*block_x]
  lo ref:     (1, Y, Z)         <- plane i*block_x - 1   (padded outside)
  hi ref:     (1, Y, Z)         <- plane (i+1)*block_x   (padded outside)
  out ref:    (block_x, Y, Z)   -> global flat id of the steepest neighbor
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.steepest import neighbor_offsets


def _kernel(center, lo, hi, out, *, offsets, block_x, R, fill):
    i = pl.program_id(0)
    ext = jnp.concatenate([lo[...], center[...], hi[...]], axis=0)
    z = ext.shape[2]
    # global flat ids of the extended tile (row-major, x-major layout)
    base = (i * block_x - 1) * R
    gids = base + jax.lax.broadcasted_iota(jnp.int32, ext.shape, 0) * R \
        + jax.lax.broadcasted_iota(jnp.int32, ext.shape, 1) * z \
        + jax.lax.broadcasted_iota(jnp.int32, ext.shape, 2)

    def shifted(a, off, fill_val):
        """a[p + off] within the ext tile, fill outside (static shifts)."""
        pads = [(max(-o, 0), max(o, 0)) for o in off]
        padded = jnp.pad(a, pads, constant_values=fill_val)
        sl = tuple(slice(max(o, 0), max(o, 0) + s)
                   for o, s in zip(off, a.shape))
        return padded[sl]

    # stacked candidates + ONE argmax (not chained per-offset selects, which
    # send XLA:CPU fusion into minutes-long compiles at connectivity >= 14);
    # self is candidate 0, so first-max-wins keeps self on ties — ties only
    # occur at the inert fill value
    cand_val = jnp.stack([ext] + [shifted(ext, off, fill)
                                  for off in offsets])
    cand_idx = jnp.stack([gids] + [shifted(gids, off, -1)
                                   for off in offsets])
    choice = jnp.argmax(cand_val, axis=0)
    out[...] = jnp.take_along_axis(cand_idx, choice[None], axis=0)[0][1:-1]


@functools.partial(jax.jit,
                   static_argnames=("connectivity", "block_x", "interpret"))
def steepest_neighbor(order: jax.Array, connectivity: int = 6,
                      block_x: int = 8, interpret: bool = True) -> jax.Array:
    """order: (X, Y, Z) int32 (unique values >= 0).  Returns (X, Y, Z) int32
    global flat ids.  On-domain boundary handled by -fill halo planes."""
    if order.ndim != 3:
        raise ValueError(
            f"steepest_neighbor is a 3-D x-slab kernel; got a {order.ndim}-D "
            f"field of shape {order.shape} — repro.kernels.ops dispatches "
            "such inputs to the jnp grid_steepest fallback")
    try:
        offsets = neighbor_offsets(3, connectivity)
    except ValueError as e:
        raise ValueError(
            f"steepest_neighbor: connectivity {connectivity} has no 3-D "
            "offset table; repro.kernels.ops dispatches it to the jnp "
            "fallback") from e
    x, y, z = order.shape
    if x % block_x:
        block_x = 1
    fill = jnp.iinfo(order.dtype).min
    nblk = x // block_x
    # pre-sliced halo planes: lo[i] = order[i*bx - 1], hi[i] = order[(i+1)*bx]
    padded = jnp.concatenate([
        jnp.full((1, y, z), fill, order.dtype), order,
        jnp.full((1, y, z), fill, order.dtype)], axis=0)
    lo = padded[0::block_x][:nblk]
    hi = padded[block_x + 1::block_x][:nblk]

    grid = (nblk,)
    kernel = functools.partial(_kernel, offsets=offsets, block_x=block_x,
                               R=y * z, fill=fill)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_x, y, z), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, y, z), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, y, z), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_x, y, z), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((x, y, z), jnp.int32),
        interpret=interpret,
    )(order, lo, hi)
