"""Logical-axis sharding context.

Model code annotates tensors with *logical* axes ("dp", "tp", "fsdp", "sp");
the launcher binds a physical mesh and this module translates logical ->
physical PartitionSpecs, dropping axes the mesh does not have.  With no mesh
bound (unit tests, single-device smoke runs) every constraint is a no-op, so
model code never branches on topology.

Logical axes:
  dp   — batch/data parallel  -> ("pod", "data") when present
  fsdp — parameter sharding   -> ("data",)
  tp   — tensor/expert/vocab  -> ("model",)
  sp   — sequence parallel    -> ("model",)   (same physical axis as tp)
  ep_all — maximal sharding   -> ("pod", "data", "model") (long-context KV)
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL_AXES = {
    "dp": ("pod", "data"),
    "fsdp": ("data",),
    "tp": ("model",),
    "sp": ("model",),
    "ep_all": ("pod", "data", "model"),
}

_state = threading.local()


def set_current_mesh(mesh: Mesh | None):
    _state.mesh = mesh


def get_current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = get_current_mesh()
    set_current_mesh(mesh)
    try:
        yield mesh
    finally:
        set_current_mesh(prev)


def logical_to_spec(logical, mesh: Mesh) -> P:
    """Translate a tuple of logical axis names (or None) to a PartitionSpec
    for `mesh`, dropping physical axes the mesh lacks."""
    names = set(mesh.axis_names)
    out = []
    for l in logical:
        if l is None:
            out.append(None)
            continue
        phys = tuple(a for a in LOGICAL_AXES[l] if a in names)
        if not phys:
            out.append(None)
        elif len(phys) == 1:
            out.append(phys[0])
        else:
            out.append(phys)
    return P(*out)


def constrain(x, *logical):
    """with_sharding_constraint by logical axes; no-op without a bound mesh."""
    mesh = get_current_mesh()
    if mesh is None:
        return x
    spec = logical_to_spec(logical, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
