"""Batched multi-tenant topology serving (DESIGN.md §Serve / §Serve-v2).

    from repro.serve import TopologyEngine
    from repro.topology import TopologyRequest

    eng = TopologyEngine()
    results = eng.submit_batch([TopologyRequest("cc", mask=m), ...])
    eng.stats.as_dict()   # requests/batches, cache hit rate, pad waste

Async plane (queueing, deadline-aware flushing, split-retry, idempotency):

    from repro.serve import AsyncTopologyEngine, VirtualClock

    eng = AsyncTopologyEngine(clock=VirtualClock())
    h = eng.submit(req, deadline=0.5, idempotency_key="tenant-42/9001")
    eng.advance(0.5)      # deadline flush (virtual time)
    h.result()            # bit-identical to repro.topology.submit(req)
"""
from .engine import (TopologyEngine, AsyncTopologyEngine, TopologyHandle,
                     EngineStats)
from .scheduler import FlushScheduler, VirtualClock, MonotonicClock
from .bucketing import (bucket_shape, batch_capacity, remap_flat_labels,
                        merge_adjacent_layouts)

__all__ = ["TopologyEngine", "AsyncTopologyEngine", "TopologyHandle",
           "EngineStats", "FlushScheduler", "VirtualClock", "MonotonicClock",
           "bucket_shape", "batch_capacity", "remap_flat_labels",
           "merge_adjacent_layouts"]
