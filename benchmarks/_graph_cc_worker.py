"""Worker for the unstructured (graph) CC scaling benchmark: runs under
XLA_FLAGS=--xla_force_host_platform_device_count=8 in a subprocess.
Prints CSV rows:  name,us_per_call,derived

Strong scaling over vertex-partition counts {1, 2, 4, 8} on a synthetic
tet-mesh-style edge list (the Freudenthal tetrahedralization of an edge^3
grid emitted as a fully unstructured edge list), with the single-device
`connected_components_graph` as the 1-partition reference and oracle.  The
requested size is used verbatim (an edge length or an exact "XxYxZ"
extent); vertex counts that do not divide a partition count run the padded
imbalanced-partition path (deviation (p) in DESIGN.md).  The derived
column carries the cut-table exchange volume (ghost_bytes), the comm-phase
count (the paper's budget: 1), the resolution iteration counts, the
per-device table bytes / exchange rounds (DESIGN.md §Table-sharding), and
the owned-set pad fraction.

Under ``--multihost`` the worker instead joins the real multi-process mesh
(`jax.distributed.initialize()`, coordinator from the launcher env) and
runs every partition count that fits the global device count."""
import os
import sys

if "--multihost" in sys.argv:
    import jax
    jax.distributed.initialize()
else:
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")

import time

import numpy as np
import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import make_dpc_mesh
from repro.core.connected_components import connected_components_graph
from repro.core.distributed_graph import (
    GraphDecomp, distributed_connected_components_graph)
from repro.configs.dpc_graph import SCALING_PARTS
from repro.data import perlin_noise, grid_edge_list

from _dpc_worker import _parse_size  # shared "edge or XxYxZ" spec parsing


def timeit(fn, *args, reps=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


def main():
    edge = sys.argv[1]           # edge length or exact "XxYxZ" — verbatim
    dims = _parse_size(edge)
    n = int(np.prod(dims))
    senders, receivers = grid_edge_list(dims, 14)
    field = perlin_noise(dims, frequency=0.1, seed=0)
    mask = jnp.asarray((field > np.quantile(field, 0.9)).ravel())
    sj, rj = jnp.asarray(senders), jnp.asarray(receivers)

    us, ref = timeit(
        lambda m: connected_components_graph(m, sj, rj), mask)
    print(f"tab4_graph_cc_single_{edge},{us:.0f},"
          f"edges={senders.size};rounds={int(ref.n_rounds)}", flush=True)

    ndev = len(jax.devices())
    for nparts in SCALING_PARTS:
        if nparts > ndev:
            print(f"# skipping {nparts} partitions ({ndev} devices)",
                  file=sys.stderr)
            continue
        # no divisibility skip: a non-dividing count pads the owned sets
        dec = GraphDecomp(n, senders, receivers, nparts)
        mesh = make_dpc_mesh(nparts)
        us, (labels, stats) = timeit(
            lambda m: distributed_connected_components_graph(m, dec, mesh),
            mask)
        assert (np.asarray(labels) == np.asarray(ref.labels)).all(), nparts
        print(f"tab4_graph_cc_{edge}_{nparts}parts,{us:.0f},"
              f"ghost_bytes={int(stats.ghost_bytes)};"
              f"comm_phases={int(stats.comm_phases)};"
              f"table_iters={int(stats.table_iters)};"
              f"stitch_rounds={int(stats.stitch_rounds)};"
              f"table_bytes={int(stats.table_bytes_peak)};"
              f"exchange_rounds={int(stats.exchange_rounds)};"
              f"pad_frac={float(stats.pad_fraction):.4f}", flush=True)


if __name__ == "__main__":
    main()
