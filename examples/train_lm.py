"""End-to-end driver: train a (reduced) LM for a few hundred steps with the
full production loop — sharded step, async checkpoints, injected failure +
restart, straggler monitoring — and verify the loss drops.

  PYTHONPATH=src python examples/train_lm.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import main as train_main


if __name__ == "__main__":
    loss, report = train_main([
        "--arch", "llama3.2-1b", "--smoke", "--steps", "200",
        "--batch", "8", "--seq", "64", "--lr", "3e-3",
        "--ckpt-dir", "/tmp/repro_example_ckpt", "--ckpt-every", "50",
        "--chaos",
    ])
    assert report["restarts"] == 1, "chaos restart must have happened"
    assert loss < 4.0, f"planted bigram structure not learned: {loss}"
    print(f"OK: trained through an injected failure to eval loss {loss:.3f}")
