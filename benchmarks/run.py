"""Benchmark harness — one function per paper table, plus framework
microbenches.  Prints ``name,us_per_call,derived`` CSV.

  tab1_strong_scaling — paper Tab. 1: MS segmentation + DPC-CC wall time vs
      shard count at fixed grid size (8 fake host devices, subprocess)
  tab2_weak_scaling   — paper Tab. 2: per-shard grid held constant
  tab3_threshold      — paper Tab. 3: implicit DPC-CC vs the VTK stand-in
      (label propagation + explicit extraction memory model) at top
      10% / 50% / 90% masks
  tab4_graph_cc_scaling — paper §5 unstructured path: distributed graph CC
      over vertex-partition counts {1,2,4,8} of a synthetic tet-mesh edge
      list vs the single-device oracle
  alg_doubling_vs_wave — the log(d) vs O(d) round-count gap that drives the
      paper's algorithm choice
  kernels             — Pallas hot-spot kernels vs their jnp oracles
  lm_train_microbench — framework-side: smoke-LM train-step latency
"""
import os
import subprocess
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp


def timeit(fn, *args, reps: int = 3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6, out


_ROWS = []  # every _emit row; main() dumps the kernel/alg subset as JSON


def _emit(name, us, derived=""):
    _ROWS.append({"name": name, "us_per_call": round(us),
                  "derived": derived})
    print(f"{name},{us:.0f},{derived}", flush=True)


def _run_scaling_worker(worker_file, argv, *, multihost=False, name=""):
    """Spawn a scaling worker.  Default: 8 fake host devices (the CI
    single-host stand-in).  `multihost=True` instead hands the worker the
    REAL multi-process device set: the worker calls
    `jax.distributed.initialize()` (coordinator address / process ids come
    from the launcher env, e.g. srun or the JobSet controller) and layouts
    span the global device count — the path the paper's >= 64-rank tables
    need."""
    env = dict(os.environ)
    if not multihost:
        env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    worker = os.path.join(os.path.dirname(__file__), worker_file)
    cmd = [sys.executable, worker] + [str(a) for a in argv]
    if multihost:
        cmd.append("--multihost")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=3600)
    sys.stdout.write(proc.stdout)
    if proc.returncode:
        sys.stderr.write(proc.stderr)
        raise RuntimeError(f"{name or worker_file} worker failed")


def tab1_strong_scaling(base="96", multihost=False):
    """base: edge length or an exact "XxYxZ" size (e.g. 97x61x43) — passed
    through verbatim; non-divisible shapes run the pad-and-mask path and the
    report carries the per-block pad fraction."""
    _run_scaling_worker("_dpc_worker.py", ["strong", base],
                        multihost=multihost, name="strong-scaling")


def tab2_weak_scaling(base="48", multihost=False):
    _run_scaling_worker("_dpc_worker.py", ["weak", base],
                        multihost=multihost, name="weak-scaling")


def tab4_graph_cc_scaling(edge="24", multihost=False):
    """Unstructured CC strong scaling (paper §5, the graph path): vertex
    partitions {1, 2, 4, 8} of a synthetic tet-mesh edge list vs the
    single-device oracle; derived columns expose the one-phase cut-table
    exchange (ghost_bytes / comm_phases) and the owned-set pad fraction.
    edge: grid edge length or an exact "XxYxZ" size; counts that do not
    divide the partition count run the padded (imbalanced) path."""
    _run_scaling_worker("_graph_cc_worker.py", [edge],
                        multihost=multihost, name="graph-CC scaling")


def table_scaling(size="24", multihost=False):
    """Replicated vs sharded boundary table (DESIGN.md §Table-sharding):
    one grid across block lattices (2,) / (2, 2) / (2, 2, 2), manifold and
    CC, both table modes — the derived columns carry per-device table bytes
    and outer exchange rounds, and the worker writes BENCH_table.json (the
    artifact CI archives).  size: edge length or exact "XxYxZ", verbatim."""
    _run_scaling_worker("_table_worker.py", [size],
                        multihost=multihost, name="table-scaling")


def tab3_threshold(edge: int = 96):
    """Implicit DPC-CC vs label-propagation baseline across mask fractions;
    derived column carries the paper's memory argument: implicit needs ONE
    id array, explicit extraction materialises the masked edge list."""
    from repro.core.connected_components import connected_components_grid
    from repro.core.baseline_cc import label_propagation_grid
    from repro.data import perlin_noise
    field = perlin_noise((edge, edge, edge), frequency=0.1, seed=3)
    n = field.size
    for frac, name in ((0.9, "top10"), (0.5, "top50"), (0.1, "top90")):
        mask = jnp.asarray(field > np.quantile(field, frac))
        us_dpc, res = timeit(
            lambda m: connected_components_grid(m, 6), mask, reps=2)
        us_lp, base = timeit(
            lambda m: label_propagation_grid(m, 6), mask, reps=2)
        assert (np.asarray(res.labels) == np.asarray(base.labels)).all()
        n_masked = int(mask.sum())
        implicit_mb = 4 * n / 2**20                   # one int32 label array
        explicit_mb = (2 * 4 * 6 * n_masked) / 2**20  # directed edge list
        _emit(f"tab3_dpc_implicit_{name}_{edge}", us_dpc,
              f"mem_mb={implicit_mb:.1f};rounds={int(res.n_rounds)}")
        _emit(f"tab3_baseline_wave_{name}_{edge}", us_lp,
              f"mem_mb={explicit_mb:.1f};rounds={int(base.n_rounds)}")


def alg_doubling_vs_wave(edge: int = 512):
    """2D snake: component diameter ~ n; pointer doubling needs O(log n)
    rounds, wave propagation O(n) — the core algorithmic claim."""
    from repro.core.connected_components import connected_components_grid
    from repro.core.baseline_cc import label_propagation_grid
    mask = np.zeros((edge, 64), bool)
    mask[:, ::2] = True
    for i in range(0, 64 - 2, 4):                      # serpentine
        mask[-1, i:i + 2] = True
        mask[0, i + 2:i + 4] = True
    m = jnp.asarray(mask)
    us_dpc, res = timeit(lambda x: connected_components_grid(x, 4), m, reps=2)
    us_lp, base = timeit(lambda x: label_propagation_grid(x, 4), m, reps=2)
    assert (np.asarray(res.labels) == np.asarray(base.labels)).all()
    _emit(f"alg_pointer_doubling_snake_{edge}", us_dpc,
          f"compress_iters={int(res.n_compress_iter)}")
    _emit(f"alg_wave_propagation_snake_{edge}", us_lp,
          f"rounds={int(base.n_rounds)}")

    # 3-D snake through the distributed hot path: the fused kernel saturates
    # each x-slab in VMEM, so the global doubling loop starts near-converged
    # — DPCStats.kernel_rounds certifies the rounds moved off the global
    # loop (DESIGN.md §Perf).  mesh(1) keeps the bench single-device; the
    # kernel runs in interpret mode on CPU.
    from repro.core.distributed import (make_dpc_mesh,
                                        distributed_connected_components)
    snake = np.zeros((edge, 32, 2), bool)
    snake[:, ::2, 0] = True
    for i in range(0, 32 - 2, 4):                      # serpentine in z=0
        snake[-1, i:i + 2, 0] = True
        snake[0, i + 2:i + 4, 0] = True
    m3 = jnp.asarray(snake)
    mesh = make_dpc_mesh(1)
    us_ref, (l_ref, s_ref) = timeit(
        lambda x: distributed_connected_components(x, mesh, 6,
                                                   fused_impl="ref"),
        m3, reps=1)
    us_fus, (l_fus, s_fus) = timeit(
        lambda x: distributed_connected_components(x, mesh, 6,
                                                   fused_impl="kernel"),
        m3, reps=1)
    assert (np.asarray(l_ref) == np.asarray(l_fus)).all()
    kr, li_f = int(s_fus.kernel_rounds), int(s_fus.local_iters)
    li_r = int(s_ref.local_iters)
    assert kr >= 1 and li_f < li_r, (
        f"fused local phase must strictly reduce global doubling rounds: "
        f"kernel_rounds={kr}, local_iters {li_r} -> {li_f}")
    _emit(f"alg_unfused_local_phase_snake3d_{edge}", us_ref,
          f"local_iters={li_r};kernel_rounds=0")
    _emit(f"alg_fused_local_phase_snake3d_{edge}", us_fus,
          f"local_iters={li_f};kernel_rounds={kr};"
          f"saved={int(s_fus.global_iters_saved)}")


def kernels():
    from repro.kernels.steepest_neighbor import steepest_neighbor
    from repro.kernels import ref
    from repro.core.steepest import neighbor_offsets
    rng = np.random.default_rng(0)
    order = jnp.asarray(rng.permutation(64 * 64 * 64)
                        .reshape(64, 64, 64).astype(np.int32))
    us_k, _ = timeit(lambda o: steepest_neighbor(o, 6, block_x=16,
                                                 interpret=True), order,
                     reps=1)
    us_r, _ = timeit(lambda o: ref.steepest_neighbor_ref(
        o, neighbor_offsets(3, 6)), order, reps=2)
    _emit("kernel_steepest_pallas_interp_64", us_k, "interpret=True")
    _emit("kernel_steepest_ref_64", us_r, "jnp oracle")

    # fused init + in-tile saturation vs the bit-exact host oracle (the
    # parity assert keeps the bench honest: pointers AND rounds must match)
    from repro.kernels.fused_local_phase import fused_local_phase
    order32 = jnp.asarray(rng.permutation(32 * 32 * 32)
                          .reshape(32, 32, 32).astype(np.int32))
    us_fk, (fp, fr) = timeit(
        lambda o: fused_local_phase(o, 6, mode="manifold", block_x=8,
                                    interpret=True), order32, reps=1)
    want, wr = ref.fused_local_phase_ref(order32, 6, mode="manifold",
                                         block_x=8)
    assert (np.asarray(fp) == np.asarray(want)).all()
    assert int(fr) == int(wr) >= 1
    us_fr, _ = timeit(lambda o: ref.fused_local_phase_ref(
        o, 6, mode="manifold", block_x=8), order32, reps=1)
    _emit("kernel_fused_local_phase_pallas_interp_32", us_fk,
          f"interpret=True;rounds={int(fr)}")
    _emit("kernel_fused_local_phase_ref_32", us_fr, "host oracle")

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (1, 4, 256, 64))
    k = jax.random.normal(k2, (1, 4, 256, 64))
    v = jax.random.normal(k3, (1, 4, 256, 64))
    us_f, _ = timeit(lambda a, b, c: ref.flash_attention_ref(
        a, b, c, causal=True), q, k, v, reps=2)
    _emit("kernel_flash_ref_256", us_f, "chunked-softmax jnp")

    from repro.kernels.segment_bag import segment_bag
    from repro.models.bst import embedding_bag
    tab = jax.random.normal(jax.random.PRNGKey(4), (4096, 32))
    ids = jax.random.randint(jax.random.PRNGKey(5), (512, 16), -1, 4096)
    us_b, _ = timeit(lambda t_, i_: segment_bag(
        t_, i_, vocab_block=1024, batch_block=256, interpret=True), tab, ids,
        reps=1)
    us_r, _ = timeit(lambda t_, i_: embedding_bag(t_, i_), tab, ids, reps=2)
    _emit("kernel_segment_bag_pallas_interp", us_b, "interpret=True")
    _emit("kernel_segment_bag_ref", us_r, "take+segment_sum jnp")


def serve_throughput(n_requests: int = 24, repeat: int = 3,
                     arrival: str = "closed"):
    """Batched multi-tenant serving (DESIGN.md §Serve): replay one mixed
    CC / MS / manifold / threshold-sweep request sequence through the
    TopologyEngine.  Pass 0 compiles one executable per layout bucket; the
    remaining passes replay the same layouts and are served from the
    executable cache, so the warm row is the steady-state requests/sec.
    Derived columns carry the serving balance sheet: cache hit rate and the
    pad fraction of the bucketed layouts (the bounded-padding budget).
    Sizes come from configs/serve_topology.py smoke_config — the bench
    measures the serving layer (bucketing, batching, cache), not kernel
    FLOPs, so small prime extents are the interesting regime.

    `arrival="open"` additionally runs the async plane (DESIGN.md
    §Serve-v2): first the SAME closed burst through `AsyncTopologyEngine`
    (the apples-to-apples throughput comparison — the acceptance gate is
    that the async plane's bookkeeping does not cost warm req/s), then an
    open-loop pass with Poisson arrivals and per-request deadlines on a
    virtual clock with measured execution wall time charged in — the row
    that carries deadline-hit rate and latency percentiles.  The async rows
    land in BENCH_serve_async.json along with the replayable trace."""
    from repro import configs
    from repro.serve import TopologyEngine
    from repro.serve.workload import synthetic_requests

    cfg = configs.get("serve_topology").smoke_config()
    eng = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch)
    reqs = synthetic_requests(n_requests, cfg.shapes, mix=cfg.mix,
                              connectivity=cfg.connectivity,
                              sweep_k=cfg.sweep_k, seed=0)
    t0 = time.perf_counter()
    eng.submit_batch(reqs)
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(max(repeat - 1, 1)):
        eng.submit_batch(reqs)
    warm = (time.perf_counter() - t0) / max(repeat - 1, 1)
    s = eng.stats
    _emit(f"serve_throughput_cold_{n_requests}", cold / n_requests * 1e6,
          f"rps={n_requests / cold:.1f};hit_rate=0.00;"
          f"pad_fraction={s.pad_fraction:.2f}")
    _emit(f"serve_throughput_warm_{n_requests}", warm / n_requests * 1e6,
          f"rps={n_requests / warm:.1f};hit_rate={s.hit_rate:.2f};"
          f"pad_fraction={s.pad_fraction:.2f};executables={len(eng._exec)}")
    assert s.hit_rate >= 0.5, (
        f"repeated-layout hit rate {s.hit_rate:.2f} < 0.5")
    if arrival != "open":
        return

    from repro.serve import AsyncTopologyEngine, VirtualClock
    from repro.serve.workload import synthetic_trace
    sync_warm_rps = n_requests / warm

    # (1) closed burst through the async plane: identical executions once
    # warm, so any gap vs the sync engine is pure request-plane overhead
    aeng = AsyncTopologyEngine(min_extent=cfg.min_extent,
                               max_batch=cfg.max_batch,
                               clock=VirtualClock())

    def closed_pass():
        t0 = time.perf_counter()
        hs = [aeng.submit(r) for r in reqs]
        aeng.drain()
        assert all(h.done() for h in hs)
        return time.perf_counter() - t0

    closed_pass()                                     # compile
    warm_async = min(closed_pass() for _ in range(max(repeat - 1, 1)))
    async_rps = n_requests / warm_async
    _emit(f"serve_async_closed_warm_{n_requests}",
          warm_async / n_requests * 1e6,
          f"rps={async_rps:.1f};hit_rate={aeng.stats.hit_rate:.2f};"
          f"vs_sync={async_rps / sync_warm_rps:.2f}")

    # (2) open-loop: trace arrivals + deadlines, virtual time, execution
    # wall time charged into the clock so deadline hits reflect real cost
    trace = synthetic_trace(n_requests, cfg.shapes, mix=cfg.mix,
                            connectivity=cfg.connectivity,
                            sweep_k=cfg.sweep_k, seed=0, rate=cfg.rate,
                            deadline_slack=cfg.deadline_slack)
    oeng = AsyncTopologyEngine(min_extent=cfg.min_extent,
                               max_batch=cfg.max_batch,
                               cache_capacity=cfg.cache_capacity,
                               slot_cost_cells=cfg.slot_cost_cells or None,
                               clock=VirtualClock(),
                               charge_execution_time=True)

    def open_pass():
        base = oeng.clock.now()
        t0 = time.perf_counter()
        hs = []
        for req, (t, dl) in zip(trace.requests(), trace.arrivals):
            tt = base + t
            if tt > oeng.clock.now():
                oeng.advance(tt - oeng.clock.now())
            hs.append(oeng.submit(
                req, deadline=None if dl is None else base + dl))
        oeng.drain()
        assert all(h.done() for h in hs)
        return time.perf_counter() - t0

    open_pass()                                       # cold (compiles)
    n_cold = len(oeng.latencies)
    hits0, miss0 = oeng.stats.deadline_hits, oeng.stats.deadline_misses
    wall_open = open_pass()                           # warm, measured
    so = oeng.stats
    lat = np.asarray(oeng.latencies[n_cold:], dtype=float)
    p50 = float(np.percentile(lat, 50)) if lat.size else 0.0
    p99 = float(np.percentile(lat, 99)) if lat.size else 0.0
    warm_hits = so.deadline_hits - hits0
    warm_total = warm_hits + (so.deadline_misses - miss0)
    dhr = warm_hits / warm_total if warm_total else 1.0
    assert (so.flush_capacity + so.flush_deadline + so.flush_drain
            + so.flush_retry == so.batches)
    _emit(f"serve_async_open_warm_{n_requests}",
          wall_open / n_requests * 1e6,
          f"rps={n_requests / wall_open:.1f};deadline_hit_rate={dhr:.2f};"
          f"p50_ms={p50 * 1e3:.2f};p99_ms={p99 * 1e3:.2f};"
          f"evictions={so.cache_evictions};"
          f"queue_peak={so.queue_depth_peak}")

    # (3) overload: the SAME workload shape arriving at overload_factor x
    # the measured sustainable rate against tight admission budgets and
    # shed_policy="hopeless" (DESIGN.md §Serve-v3).  The engine attaches to
    # the open-loop engine's SharedExecutableCache, so it starts warm and
    # the row measures overload POLICY, not compile cost.  The reject/shed
    # rates are the bench's overload balance sheet.
    from repro.serve import PlaneError
    from repro.serve.workload import overload_trace
    otrace = overload_trace(n_requests, cfg.shapes, mix=cfg.mix,
                            connectivity=cfg.connectivity,
                            sweep_k=cfg.sweep_k, seed=1,
                            sustainable_rps=sync_warm_rps,
                            factor=cfg.overload_factor)
    xeng = AsyncTopologyEngine(min_extent=cfg.min_extent,
                               max_batch=cfg.max_batch,
                               slot_cost_cells=cfg.slot_cost_cells or None,
                               clock=VirtualClock(),
                               charge_execution_time=True,
                               max_queue_depth=cfg.overload_queue_depth,
                               max_inflight_cells=cfg.max_inflight_cells,
                               shed_policy="hopeless",
                               default_estimate=1.0 / sync_warm_rps,
                               compile_cache=oeng.cache, name="overload")
    t0 = time.perf_counter()
    ohs = []
    for req, (t, dl) in zip(otrace.requests(), otrace.arrivals):
        if t > xeng.clock.now():
            xeng.advance(t - xeng.clock.now())
        ohs.append(xeng.submit(req, deadline=dl))
    xeng.drain()
    wall_over = time.perf_counter() - t0
    sx = xeng.stats
    assert all(h.done() for h in ohs)
    for h in ohs:
        assert h.exception() is None or isinstance(h.exception(), PlaneError)
    assert sx.rejected + sx.shed > 0, (
        f"{cfg.overload_factor}x overload produced no rejections/sheds")
    assert sx.completed + sx.failures + sx.shed == sx.requests
    reject_rate = sx.rejected / n_requests
    shed_rate = sx.shed / n_requests
    _emit(f"serve_async_overload_{n_requests}",
          wall_over / n_requests * 1e6,
          f"factor={cfg.overload_factor:.0f};completed={sx.completed};"
          f"reject_rate={reject_rate:.2f};shed_rate={shed_rate:.2f};"
          f"depth_limited={sx.queue_depth_limit}")

    import json
    out = os.path.join(os.getcwd(), "BENCH_serve_async.json")
    with open(out, "w") as f:
        json.dump({
            "sync_warm_rps": sync_warm_rps,
            "async_closed_warm_rps": async_rps,
            "open_loop": {
                "warm_rps": n_requests / wall_open,
                "deadline_hit_rate": dhr,
                "latency_p50_ms": p50 * 1e3,
                "latency_p99_ms": p99 * 1e3,
                "flush_reasons": {
                    "capacity": so.flush_capacity,
                    "deadline": so.flush_deadline,
                    "drain": so.flush_drain,
                    "retry": so.flush_retry},
                "cache_evictions": so.cache_evictions,
                "queue_depth_peak": so.queue_depth_peak,
            },
            "overload": {
                "factor": cfg.overload_factor,
                "completed": sx.completed,
                "rejected": sx.rejected,
                "shed": sx.shed,
                "reject_rate": reject_rate,
                "shed_rate": shed_rate,
                "queue_depth_limit": sx.queue_depth_limit,
                "max_queue_depth": cfg.overload_queue_depth,
                "shared_cache": xeng.cache.info(),
                "trace": otrace.as_dict(),
            },
            "trace": trace.as_dict(),
        }, f, indent=2)
        f.write("\n")
    print(f"# wrote {out}", file=sys.stderr)
    # the acceptance gate, with head-room for CI timer noise: the async
    # plane must not cost warm throughput vs the synchronous engine
    assert async_rps >= 0.75 * sync_warm_rps, (
        f"async warm {async_rps:.1f} req/s < 0.75x sync warm "
        f"{sync_warm_rps:.1f} req/s")


def lm_train_microbench():
    from repro import configs
    from repro.models import lm
    from repro.optim import adamw
    cfg = configs.get("llama3_2_1b").smoke_config()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw(1e-3)
    state = opt.init(params)
    toks = jax.random.randint(jax.random.PRNGKey(1), (8, 64), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    @jax.jit
    def step(params, state, batch):
        (l, m), g = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, batch, cfg)
        params, state, _ = opt.update(g, state, params)
        return params, state, l

    us, _ = timeit(lambda p, s, b: step(p, s, b), params, state, batch,
                   reps=3)
    _emit("lm_train_step_smoke_8x64", us, f"params={cfg.n_params()}")


# name -> (fn, default kwargs, --tiny kwargs for the CI smoke step).
# Tiny scaling sizes are PRIME on purpose: the nightly bench artifact then
# exercises the ragged pad-and-mask path on every layout.
_BENCHES = {
    "tab3_threshold": (tab3_threshold, {"edge": 64}, {"edge": 24}),
    "alg_doubling_vs_wave": (alg_doubling_vs_wave, {"edge": 256},
                             {"edge": 64}),
    "kernels": (kernels, {}, {}),
    "lm_train_microbench": (lm_train_microbench, {}, {}),
    "serve_throughput": (serve_throughput, {"n_requests": 24, "repeat": 3},
                         {"n_requests": 8, "repeat": 2}),
    "tab1_strong_scaling": (tab1_strong_scaling, {"base": 64},
                            {"base": 17}),
    "tab2_weak_scaling": (tab2_weak_scaling, {"base": 32}, {"base": 8}),
    "tab4_graph_cc_scaling": (tab4_graph_cc_scaling, {"edge": 24},
                              {"edge": 7}),
    "table_scaling": (table_scaling, {"size": 48}, {"size": 13}),
}

# benches that accept an exact user size via --size= (passed through
# verbatim — sizes are never rounded to divisible shapes)
_SIZED = {"tab1_strong_scaling": "base", "tab2_weak_scaling": "base",
          "tab4_graph_cc_scaling": "edge", "table_scaling": "size"}

# subprocess scaling benches that can run on a real multi-process mesh
_MULTIHOST = {"tab1_strong_scaling", "tab2_weak_scaling",
              "tab4_graph_cc_scaling", "table_scaling"}


def main(argv=None) -> None:
    """Usage: run.py [--tiny] [--size=XxYxZ] [--multihost] [bench ...] — no
    names runs everything.  --size passes the user's exact size through to
    the scaling benches (any extent: non-divisible shapes take the padded
    path and the report prints the pad fraction per block).  --multihost
    runs the subprocess scaling benches on the real multi-process device
    set via `jax.distributed.initialize()` (launcher env provides the
    coordinator) instead of 8 fake host devices.  Output is CSV on stdout
    (CI redirects it into an artifact)."""
    argv = sys.argv[1:] if argv is None else argv
    tiny = "--tiny" in argv
    multihost = "--multihost" in argv
    size = None
    arrival = "closed"
    for a in argv:
        if a.startswith("--size="):
            size = a.split("=", 1)[1]
        if a.startswith("--arrival="):
            arrival = a.split("=", 1)[1]
    if arrival not in ("closed", "open"):
        sys.exit(f"--arrival must be closed or open, got {arrival!r}")
    names = [a for a in argv if not a.startswith("-")]
    bad_flags = [a for a in argv
                 if a.startswith("-") and a not in ("--tiny", "--multihost")
                 and not a.startswith("--size=")
                 and not a.startswith("--arrival=")]
    if bad_flags:
        sys.exit(f"unknown flag(s) {bad_flags}; flags are --tiny, "
                 "--size=XxYxZ, --arrival=closed|open and --multihost")
    if multihost:
        non_mh = [n for n in (names or list(_BENCHES)) if n not in _MULTIHOST]
        if non_mh:
            sys.exit(f"--multihost only applies to {sorted(_MULTIHOST)}; "
                     f"drop {non_mh} or run them separately")
    unknown = [n for n in names if n not in _BENCHES]
    if unknown:
        sys.exit(f"unknown benchmark(s) {unknown}; "
                 f"available: {', '.join(_BENCHES)}")
    print("name,us_per_call,derived")
    for n in names or list(_BENCHES):
        fn, full_kw, tiny_kw = _BENCHES[n]
        kw = dict(tiny_kw if tiny else full_kw)
        if size is not None and n in _SIZED:
            kw[_SIZED[n]] = size
        if n == "serve_throughput":
            kw["arrival"] = arrival
        if n in _MULTIHOST:
            kw["multihost"] = multihost
        fn(**kw)
    # kernel-facing rows also land in a JSON artifact (BENCH_kernels.json):
    # the fused-vs-unfused round counts are the acceptance numbers of the
    # fused-local-phase kernel, and JSON keeps them machine-comparable
    # across nightly runs without parsing the CSV
    kernel_rows = [r for r in _ROWS
                   if r["name"].startswith(("kernel_", "alg_"))]
    if kernel_rows:
        import json
        out = os.path.join(os.getcwd(), "BENCH_kernels.json")
        with open(out, "w") as f:
            json.dump({"rows": kernel_rows}, f, indent=2)
            f.write("\n")
        print(f"# wrote {out} ({len(kernel_rows)} kernel rows)",
              file=sys.stderr)


if __name__ == "__main__":
    main()
