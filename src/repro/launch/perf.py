import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (same rule as dryrun.py).

"""§Perf hillclimbing: lower+compile optimized variants of the chosen
cells, record before/after against the baseline dry-run artifacts.

  PYTHONPATH=src python -m repro.launch.perf [--cell kimi_k2_1t:train_4k]
"""
import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_production_mesh
from repro.launch.dryrun import run_cell

# (cell, variant_tag, hypothesis, cfg_transform)
VARIANTS = []


def _v(cell, tag, hypothesis, transform):
    VARIANTS.append((cell, tag, hypothesis, transform))


# --- 1. kimi-k2-1t:train_4k — the scale cell (collective-bound baseline) ----

_v("kimi_k2_1t:train_4k", "local_dispatch",
   "MoE dispatch sorts all 1M global tokens -> GSPMD cross-shard sort + "
   "(E*C,d) dispatch buffers sized by GLOBAL capacity (~9.4GiB/dev). "
   "Shard-local dispatch (sort per data shard, experts combine via the "
   "existing TP reduce) should cut collective bytes severalfold and temp "
   "memory by ~dp x.",
   lambda cfg: dataclasses.replace(
       cfg, moe=dataclasses.replace(cfg.moe, dispatch="local")))

_v("kimi_k2_1t:train_4k", "local_bf16mom_remat",
   "On top of local dispatch: bf16 Adam moments halve optimizer HBM "
   "(state is the HBM floor for 1T params on 512 chips); remat of the "
   "flash scan + CE chunks trades ~5% recompute FLOPs for the transient "
   "backward buffers.",
   lambda cfg: dataclasses.replace(
       cfg, moe=dataclasses.replace(cfg.moe, dispatch="local"),
       opt_moment_dtype=jnp.bfloat16, remat_attn=True, remat_loss=True))

_v("kimi_k2_1t:train_4k", "shardmap_dispatch",
   "GSPMD cannot localise the batched dispatch (iter 1/2 refuted); a "
   "manually-partitioned shard_map interior — local sort, local gather, "
   "local expert FFN, ONE psum over 'model' — removes the dispatch "
   "all-to-all AND the replicated scatter buffers by construction.",
   lambda cfg: dataclasses.replace(
       cfg, moe=dataclasses.replace(cfg.moe, dispatch="shard_map")))

_v("kimi_k2_1t:train_4k", "shardmap_bf16mom_remat",
   "shard_map dispatch + bf16 moments + remat: the combined candidate.",
   lambda cfg: dataclasses.replace(
       cfg, moe=dataclasses.replace(cfg.moe, dispatch="shard_map"),
       opt_moment_dtype=jnp.bfloat16, remat_attn=True, remat_loss=True))

_v("kimi_k2_1t:train_4k", "global_bf16mom_remat",
   "Keep the (baseline) global dispatch — the local variant's scatter "
   "replication costs more than its all-to-all saves — and take the "
   "confirmed wins only: bf16 moments (optimizer HBM /2) + remat of "
   "flash/CE backward buffers.",
   lambda cfg: dataclasses.replace(
       cfg, opt_moment_dtype=jnp.bfloat16, remat_attn=True,
       remat_loss=True))

# --- 2. dimenet:ogb_products — most collective/memory-pathological ----------

_v("dimenet:ogb_products", "chunked_triplets",
   "The triplet gather materialises (T=247M, n_bilinear, d) in one shot "
   "(~422GiB/dev temp). Chunking the triplet list 64-way bounds the live "
   "set to 1/64 while keeping the same total gather traffic.",
   lambda cfg: dataclasses.replace(cfg, triplet_chunks=64))

_v("dimenet:ogb_products", "chunked_bf16_msgs",
   "Edge messages cross shards as f32; carrying the gather in bf16 halves "
   "the dominant all-gather bytes (collective term /2) at negligible "
   "accuracy cost for message passing.",
   lambda cfg: dataclasses.replace(cfg, triplet_chunks=64,
                                   msg_dtype=jnp.bfloat16))

# --- 3. stablelm-12b:train_4k — worst dense memory overshoot ----------------

_v("stablelm_12b:train_4k", "remat_attn_loss",
   "Baseline temp is 17.7GiB/dev (> 16GiB HBM): the backward keeps "
   "per-kv-block flash carries and per-chunk CE logits. Checkpointing "
   "both recomputes them in bwd: expect temp to drop below HBM with "
   "<=2 extra fwd passes of those subgraphs (compute term +~10%).",
   lambda cfg: dataclasses.replace(cfg, remat_attn=True, remat_loss=True))

_v("stablelm_12b:train_4k", "remat_bf16mom",
   "On top: bf16 moments halve optimizer state (24GiB global saved).",
   lambda cfg: dataclasses.replace(cfg, remat_attn=True, remat_loss=True,
                                   opt_moment_dtype=jnp.bfloat16))

_v("stablelm_12b:train_4k", "tp_only_params",
   "The dominant collective is the per-layer FSDP weight all-gather "
   "(2x per layer with remat). 12B params TP-16-sharded are only 1.5GiB "
   "bf16 per device, so FSDP buys nothing here: dropping it (fsdp=False) "
   "should remove those all-gathers (collective term down ~2x) at the "
   "cost of replicating params across the data axis.",
   lambda cfg: dataclasses.replace(cfg, remat_attn=True, remat_loss=True,
                                   opt_moment_dtype=jnp.bfloat16,
                                   fsdp=False))

# --- bonus: the paper's own workload -----------------------------------------

_v("dpc_grid:cc_1024", "no_mask_gather",
   "The CC exchange all-gathers labels AND masks, but masks == (labels>=0)"
   " — dropping the mask gather removes 20% of the ONE communication "
   "phase's bytes with bit-identical output (paper §6 'minimize the amount"
   " of ghost vertices which need to be sent').",
   lambda cfg: dataclasses.replace(cfg, gather_mask=False))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--cell", default=None)
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    assert len(jax.devices()) == 512
    mesh = make_production_mesh(multi_pod=False)
    results = []
    for cell, tag, hypothesis, tr in VARIANTS:
        if args.cell and cell != args.cell:
            continue
        arch, shape = cell.split(":")
        print(f"[perf] {cell} :: {tag}\n  hypothesis: {hypothesis}",
              flush=True)
        try:
            rec = run_cell(arch, shape, mesh, "pod256", False, args.out,
                           cfg_transform=tr, tag=tag)
            rec["hypothesis"] = hypothesis
            rec["variant"] = tag
            with open(os.path.join(
                    args.out,
                    f"{arch}__{shape}__{tag}.json"), "w") as f:
                json.dump(rec, f, indent=1)
            results.append(rec)
        except Exception as e:  # noqa: BLE001
            print(f"[perf] FAIL {cell}:{tag}: {e}", flush=True)
    print(f"[perf] done: {len(results)} variants recorded")


if __name__ == "__main__":
    main()
