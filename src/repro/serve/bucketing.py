"""Layout bucketing for the batched topology engine (DESIGN.md §Serve).

Heterogeneous request extents are quantised to a small set of padded
layouts so that a handful of compiled executables serves every tenant:
each grid extent rounds up to the next power of two (floored at
`min_extent`), and the request count of a bucket rounds up to the next
power-of-two batch capacity.  The pad region is filled with the same inert
sentinels the distributed pad-and-mask path uses (mask False / order -1,
deviation (p) in DESIGN.md), so padding can never win an argmax, and the
capacity slack is filled with all-inert dummy items.

Because row-major raveling is the lexicographic order of the coordinates,
padding extents preserves the relative flat-id order of the real vertices;
label VALUES (largest-member flat ids) from a padded run are mapped back to
real-extent flat ids by `remap_flat_labels` — unravel in the padded shape,
ravel in the real shape — which lands exactly on the ids the unpadded
legacy call produces (the engine's bit-parity contract).
"""
from __future__ import annotations

import math

import numpy as np


def next_pow2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def bucket_shape(shape, min_extent: int = 8) -> tuple:
    """Padded layout a request of `shape` is served under."""
    return tuple(max(next_pow2(s), min_extent) for s in shape)


def batch_capacity(n_items: int, max_batch: int = 64) -> int:
    """Padded batch size of a bucket occupancy (pow2, capped)."""
    return min(next_pow2(n_items), max_batch)


def pad_to(x: np.ndarray, shape, fill) -> np.ndarray:
    """Pad a single payload up to its bucket shape with an inert fill."""
    if tuple(x.shape) == tuple(shape):
        return x
    pads = [(0, t - s) for s, t in zip(x.shape, shape)]
    return np.pad(x, pads, constant_values=fill)


def remap_flat_labels(labels, padded_shape, real_shape) -> np.ndarray:
    """Slice a padded label grid to the real extent and rewrite label values
    from padded-shape flat ids to real-shape flat ids (identity when the
    shapes agree).  Entries < 0 (unmasked) are preserved."""
    out = np.asarray(labels)[tuple(slice(0, s) for s in real_shape)]
    if tuple(padded_shape) == tuple(real_shape):
        return out
    out = out.copy()
    pos = out >= 0
    if pos.any():
        coords = np.unravel_index(out[pos].astype(np.int64), padded_shape)
        out[pos] = np.ravel_multi_index(coords, real_shape).astype(out.dtype)
    return out


def pad_waste(real_shapes, padded_shape, capacity) -> tuple:
    """(real_cells, padded_cells) of one bucket execution."""
    real = sum(math.prod(s) for s in real_shapes)
    return real, math.prod(padded_shape) * capacity


# --- cost-model layout merging (DESIGN.md §Serve-v2) -------------------------

def adjacent_layouts(small, big) -> bool:
    """Whether `small` can merge into `big` in one pow2 step: `big`
    dominates elementwise and costs at most 2x the cells (one axis
    doubled — the pow2 lattice's nearest-neighbor relation)."""
    return (len(small) == len(big) and small != big
            and all(b >= s for s, b in zip(small, big))
            and math.prod(big) <= 2 * math.prod(small))


def merge_adjacent_layouts(layout_counts: dict, slot_cost_cells: int) -> dict:
    """Cost-model merge plan over observed pow2 layouts.

    `layout_counts` maps each layout (a `bucket_shape` tuple) to the number
    of items it would serve; the returned dict maps every layout to the
    layout it should execute under (identity when unmerged).  A layout L
    merges into an adjacent layout B already in use when the modeled extra
    pad waste — `(cells(B) - cells(L)) * n_items(L)` — is cheaper than
    keeping a separate executable slot (`slot_cost_cells`, the cost model's
    exchange rate between compiled-program slots and padded cells).  Merging
    is always *correct* (any dominating layout pads inertly and
    `remap_flat_labels` restores real-extent ids bit-identically); this
    function only decides when it is *cheap*.

    Greedy smallest-first.  The documented ≤2x pad bound (DESIGN.md
    §Serve-v2) must hold for every ORIGINAL layout, not just the direct
    edge: when L (already carrying items merged down from smaller layouts)
    would itself merge into B, each rider's own cells bound B too.  The
    pre-v3 plan only checked the direct edge, so a path-compressed chain
    A -> B -> C could transitively land A on cells(C) > 2x cells(A)
    (satellite bugfix, ISSUE 10); `min_cells` tracks the smallest original
    member of each live group and vetoes such chains.
    """
    target = {L: L for L in layout_counts}
    if slot_cost_cells is None or slot_cost_cells <= 0:
        return target
    counts = dict(layout_counts)
    min_cells = {L: math.prod(L) for L in layout_counts}
    for L in sorted(layout_counts, key=lambda s: (math.prod(s), s)):
        best, best_extra = None, None
        for B in layout_counts:
            if target[B] != B or not adjacent_layouts(L, B):
                continue  # merged-away layouts cannot absorb others
            if math.prod(B) > 2 * min_cells[L]:
                continue  # would break the ≤2x bound for a rider on L
            extra = (math.prod(B) - math.prod(L)) * counts[L]
            if best is None or (extra, B) < (best_extra, best):
                best, best_extra = B, extra
        if best is not None and best_extra < slot_cost_cells:
            target[L] = best
            counts[best] = counts.get(best, 0) + counts.pop(L)
            min_cells[best] = min(min_cells[best], min_cells[L])
    for L in target:  # resolve merge chains L -> M -> N (the min_cells
        while target[target[L]] != target[L]:  # veto makes this a no-op on
            target[L] = target[target[L]]      # the pow2 lattice; kept as
    return target                              # a safety net
