import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (architecture x input-shape)
cell on the production meshes and record memory/cost/collective analysis.

  PYTHONPATH=src python -m repro.launch.dryrun                  # everything
  PYTHONPATH=src python -m repro.launch.dryrun --arch kimi-k2-1t-a32b \
      --shape train_4k --multi-pod
  PYTHONPATH=src python -m repro.launch.dryrun --smoke          # tiny configs

Results land in experiments/dryrun/<mesh>/<arch>__<shape>.json; the roofline
report (launch/roofline.py) and EXPERIMENTS.md are generated from them.
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.launch.mesh import make_production_mesh
from repro.launch.cells import build_cell, all_cells
from repro.runtime.meshctx import use_mesh

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[0-9,]*\]\S*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (partitioned,
    per-device) HLO.  'start' variants counted once; 'done' skipped."""
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    count = 0
    for m in _OP_RE.finditer(hlo_text):
        shape_txt, op = m.group(1), m.group(2)
        if f"{op}-done" in m.group(0):
            continue
        out[op] += _shape_bytes(shape_txt)
        count += 1
    out["n_collectives"] = count
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("n_collectives", "total"))
    return out


def run_cell(arch, shape_name, mesh, mesh_label, smoke, out_dir,
             cfg_transform=None, tag=""):
    t0 = time.time()
    cell = build_cell(arch, shape_name, mesh, smoke=smoke,
                      cfg_transform=cfg_transform)
    with use_mesh(mesh):
        fn = jax.jit(cell.step_fn, in_shardings=cell.arg_shardings,
                     donate_argnums=cell.donate_argnums)
        lowered = fn.lower(*cell.arg_shapes)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # pre-0.4.31 jax: one dict per program
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    rec = {
        "cell": cell.name, "mesh": mesh_label,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [int(mesh.shape[a]) for a in mesh.axis_names])),
        "smoke": smoke,
        "memory": {
            k: int(getattr(mem, k, 0) or 0)
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
        },
        "cost": {k: float(v) for k, v in (cost or {}).items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "note": cell.note,
    }
    os.makedirs(out_dir, exist_ok=True)
    fname = f"{arch.replace('-', '_')}__{shape_name}" + \
        (f"__{tag}" if tag else "") + ".json"
    with open(os.path.join(out_dir, fname), "w") as f:
        json.dump(rec, f, indent=1)
    per_dev = rec["memory"]["argument_size_in_bytes"] + \
        rec["memory"]["temp_size_in_bytes"]
    print(f"[dryrun] OK {cell.name} @ {mesh_label} "
          f"args+temp/dev={per_dev / 2**30:.2f}GiB "
          f"flops/dev={rec['cost'].get('flops', 0):.3e} "
          f"coll={coll['total'] / 2**20:.1f}MiB "
          f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--single-pod", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dry-run needs 512 host devices"
    meshes = []
    if args.single_pod or not args.multi_pod:
        meshes.append(("pod256", make_production_mesh(multi_pod=False)))
    if args.multi_pod or not args.single_pod:
        meshes.append(("pod2x256", make_production_mesh(multi_pod=True)))

    cells = all_cells()
    if args.arch:
        cells = [(a, s) for a, s in cells
                 if a == args.arch or a.replace("_", "-") == args.arch]
    if args.shape:
        cells = [(a, s) for a, s in cells if s == args.shape]

    failures = []
    for label, mesh in meshes:
        out_dir = os.path.join(args.out, label)
        for arch, shape_name in cells:
            fname = os.path.join(
                out_dir, f"{arch.replace('-', '_')}__{shape_name}.json")
            if args.skip_existing and os.path.exists(fname):
                print(f"[dryrun] skip {arch}:{shape_name} @ {label}")
                continue
            try:
                run_cell(arch, shape_name, mesh, label, args.smoke, out_dir)
            except Exception as e:  # noqa: BLE001 — report, keep going
                failures.append((label, arch, shape_name, repr(e)))
                print(f"[dryrun] FAIL {arch}:{shape_name} @ {label}: {e}",
                      flush=True)
                traceback.print_exc()
    print(f"[dryrun] done; {len(failures)} failures")
    for f in failures:
        print("  FAIL:", *f)
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
