from .meshctx import (set_current_mesh, get_current_mesh, constrain,
                      logical_to_spec, use_mesh, LOGICAL_AXES)
