"""Synthetic recsys batches for BST (power-law item popularity, planted
sequence->click correlation so training visibly learns)."""
from __future__ import annotations

import numpy as np


def _zipf_ids(rng, vocab, shape, a: float = 1.2):
    raw = rng.zipf(a, size=shape)
    return np.minimum(raw - 1, vocab - 1).astype(np.int32)


def bst_batch(cfg, batch: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    hist = _zipf_ids(rng, cfg.item_vocab, (batch, cfg.seq_len))
    target = _zipf_ids(rng, cfg.item_vocab, (batch,))
    profile = rng.integers(0, cfg.profile_vocab,
                           (batch, cfg.n_profile_fields)).astype(np.int32)
    multihot = rng.integers(
        -1, cfg.multihot_vocab,
        (batch, cfg.n_multihot_fields, cfg.multihot_len)).astype(np.int32)
    # planted signal: click if the target item appeared in history
    click = (hist == target[:, None]).any(axis=1)
    noise = rng.random(batch) < 0.1
    labels = (click ^ noise).astype(np.float32)
    return {
        "hist_items": hist, "target_item": target,
        "profile_ids": profile, "multihot_ids": multihot,
        "labels": labels,
    }


def retrieval_batch(cfg, batch: int = 1, n_candidates: int = 1_000_000,
                    seed: int = 0):
    rng = np.random.default_rng(seed)
    b = bst_batch(cfg, batch, seed)
    b["candidates"] = rng.integers(
        0, cfg.item_vocab, (batch, n_candidates)).astype(np.int32)
    return b
