"""Distributed DPC (Alg. 1 + 2 under shard_map) == single-device labels.

Runs in a subprocess with XLA_FLAGS=--xla_force_host_platform_device_count=8
so the main test process keeps its single-device view (the dry-run rule:
never set the flag globally).
"""
import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.join(os.path.dirname(__file__), "..")

_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components,
                            descending_manifold, ascending_manifold,
                            connected_components_grid, compute_order)
    from repro.data import perlin_noise

    assert len(jax.devices()) == 8

    failures = []

    def check_manifold(shape, conn, seed, n_shards):
        rng = np.random.default_rng(seed)
        order = compute_order(jnp.asarray(rng.standard_normal(shape)))
        mesh = make_dpc_mesh(n_shards)
        for descending in (True, False):
            got, stats = distributed_manifold(order, mesh, conn, descending)
            ref, _ = (descending_manifold if descending else
                      ascending_manifold)(order, conn)
            ok = (np.asarray(got).ravel() == np.asarray(ref).ravel()).all()
            if not ok:
                failures.append(("manifold", shape, conn, seed, n_shards,
                                 descending))

    def check_cc(shape, conn, seed, n_shards, p):
        rng = np.random.default_rng(seed)
        mask = jnp.asarray(rng.random(shape) < p)
        mesh = make_dpc_mesh(n_shards)
        got, stats = distributed_connected_components(mask, mesh, conn)
        ref = connected_components_grid(mask, conn)
        ok = (np.asarray(got) == np.asarray(ref.labels)).all()
        if not ok:
            failures.append(("cc", shape, conn, seed, n_shards, p))

    # MS manifolds: 2D + 3D, both connectivities, shard counts incl Xl=1
    for n_shards in (2, 4, 8):
        check_manifold((16, 11), 4, 0, n_shards)
        check_manifold((16, 11), 6, 1, n_shards)
        check_manifold((8, 7, 6), 6, 2, n_shards)
        check_manifold((8, 7, 6), 14, 3, n_shards)
        check_manifold((8, 13), 4, 4, n_shards)     # Xl == 1 when P == 8

    # Perlin field (the paper's dataset)
    field = perlin_noise((16, 12, 10), frequency=0.2, seed=5)
    order = compute_order(jnp.asarray(field))
    mesh = make_dpc_mesh(8)
    got, stats = distributed_manifold(order, mesh, 6, True)
    ref, _ = descending_manifold(order, 6)
    assert (np.asarray(got).ravel() == np.asarray(ref).ravel()).all(), "perlin"
    assert int(stats.ghost_bytes) == 8 * 2 * 12 * 10 * 4

    # CC: sparse + dense masks, spiral adversarial case
    for n_shards in (2, 4, 8):
        for seed, p in ((0, 0.3), (1, 0.55), (2, 0.75), (3, 0.95)):
            check_cc((16, 11), 4, seed, n_shards, p)
            check_cc((8, 6, 6), 6, seed + 10, n_shards, p)
        check_cc((16, 11), 6, 20, n_shards, 0.5)
        check_cc((8, 6, 6), 14, 21, n_shards, 0.4)

    # spiral that crosses every shard repeatedly (paper Fig. 2 analogue)
    spiral = np.zeros((16, 16), bool)
    spiral[0, :] = spiral[:, 15] = True
    spiral[15, :] = spiral[2:, 0] = True
    spiral[2, 2:13] = spiral[2:13, 12] = True
    spiral[12, 2:12] = spiral[4:12, 2] = True
    spiral[4, 2:10] = True
    got, _ = distributed_connected_components(jnp.asarray(spiral),
                                              make_dpc_mesh(8), 4)
    ref = connected_components_grid(jnp.asarray(spiral), 4)
    if not (np.asarray(got) == np.asarray(ref.labels)).all():
        failures.append(("spiral",))

    # §Perf variant: dropping the mask gather must be bit-identical
    rng = np.random.default_rng(77)
    mask = jnp.asarray(rng.random((16, 9)) < 0.6)
    mesh = make_dpc_mesh(8)
    a, sa = distributed_connected_components(mask, mesh, 4, gather_mask=True)
    b, sb = distributed_connected_components(mask, mesh, 4, gather_mask=False)
    if not (np.asarray(a) == np.asarray(b)).all():
        failures.append(("gather_mask_variant",))
    assert float(sb.ghost_bytes) < float(sa.ghost_bytes)

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("DISTRIBUTED-OK")
""")


@pytest.mark.slow
def test_distributed_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "DISTRIBUTED-OK" in proc.stdout


# --- N-D block decomposition vs single-device oracles (fast CI job) ----------

_BLOCK_WORKER = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.core import (make_dpc_mesh, distributed_manifold,
                            distributed_connected_components,
                            descending_manifold, ascending_manifold,
                            connected_components_grid, compute_order)

    assert len(jax.devices()) == 8

    failures = []
    LAYOUTS = [(1,), (2,), (4,), (2, 2), (2, 4), (2, 2, 2)]

    # 3-D grid: every layout, both manifold directions on the 2-D block
    # lattices, CC at a sparse and a dense mask
    rng = np.random.default_rng(0)
    order3 = compute_order(jnp.asarray(rng.standard_normal((8, 8, 6))))
    ref_d, _ = descending_manifold(order3, 6)
    ref_a, _ = ascending_manifold(order3, 6)
    mask_s = jnp.asarray(rng.random((8, 8, 6)) < 0.35)
    mask_d = jnp.asarray(rng.random((8, 8, 6)) < 0.8)
    ref_s = connected_components_grid(mask_s, 6)
    ref_d_cc = connected_components_grid(mask_d, 6)
    for layout in LAYOUTS:
        mesh = make_dpc_mesh(layout)
        got, stats = distributed_manifold(order3, mesh, 6, True)
        if not (np.asarray(got).ravel() == np.asarray(ref_d).ravel()).all():
            failures.append(("manifold-desc", layout))
        if len(layout) > 1:
            got, _ = distributed_manifold(order3, mesh, 6, False)
            if not (np.asarray(got).ravel() == np.asarray(ref_a).ravel()).all():
                failures.append(("manifold-asc", layout))
        for mask, ref in ((mask_s, ref_s), (mask_d, ref_d_cc)):
            got, _ = distributed_connected_components(mask, mesh, 6)
            if not (np.asarray(got) == np.asarray(ref.labels)).all():
                failures.append(("cc", layout, float(mask.mean())))

    # full Freudenthal stencil (diagonal block-to-block edges) on the
    # 3-D block lattice
    mesh = make_dpc_mesh((2, 2, 2))
    got, _ = distributed_manifold(order3, mesh, 14, True)
    ref14, _ = descending_manifold(order3, 14)
    if not (np.asarray(got).ravel() == np.asarray(ref14).ravel()).all():
        failures.append(("manifold-14", (2, 2, 2)))
    got, _ = distributed_connected_components(mask_s, mesh, 14)
    ref14cc = connected_components_grid(mask_s, 14)
    if not (np.asarray(got) == np.asarray(ref14cc.labels)).all():
        failures.append(("cc-14", (2, 2, 2)))

    # 2-D grid on a 2-D block lattice, incl. the diagonal 6-stencil
    order2 = compute_order(jnp.asarray(rng.standard_normal((8, 12))))
    mesh = make_dpc_mesh((2, 4))
    got, _ = distributed_manifold(order2, mesh, 6, True)
    ref2, _ = descending_manifold(order2, 6)
    if not (np.asarray(got).ravel() == np.asarray(ref2).ravel()).all():
        failures.append(("manifold-2d", (2, 4)))
    mask2 = jnp.asarray(rng.random((8, 12)) < 0.6)
    got, _ = distributed_connected_components(mask2, mesh, 4)
    ref2cc = connected_components_grid(mask2, 4)
    if not (np.asarray(got) == np.asarray(ref2cc.labels)).all():
        failures.append(("cc-2d", (2, 4)))

    if failures:
        print("FAILURES:", failures)
        raise SystemExit(1)
    print("BLOCK-OK")
""")


def test_block_decomposition_matches_single_device():
    """Bit-identical labels vs the single-device oracles across 1-D/2-D/3-D
    shard layouts on 8 virtualized host devices (fast CI job)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _BLOCK_WORKER], env=env,
                          capture_output=True, text=True, timeout=900)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "BLOCK-OK" in proc.stdout
