"""serve-topology — configuration of the batched multi-tenant topology
query engine (`repro.serve.TopologyEngine`, DESIGN.md §Serve).

Not an ARCH_IDS member: this config parameterises the serving layer that
fronts the dpc_grid / dpc_graph workloads, not a model architecture.  The
`shapes` rotate prime and non-divisible extents on purpose so the workload
exercises the layout-bucketing path the way real datasets do.
"""
import dataclasses

FAMILY = "serve"


@dataclasses.dataclass(frozen=True)
class ServeTopologyConfig:
    name: str = "serve-topology"
    connectivity: int = 6
    # engine knobs
    min_extent: int = 8        # bucket floor: smallest padded grid extent
    max_batch: int = 64        # largest batch capacity per execution
    cache_capacity: int = 64   # bounded LRU on compiled executables
    slot_cost_cells: int = 0   # layout-merge cost model (0 disables;
                               # DESIGN.md §Serve-v2)
    # synthetic workload mix (query, weight) for benchmarks / demos
    mix: tuple = (("cc", 0.5), ("ms", 0.2), ("manifold", 0.1),
                  ("threshold_sweep", 0.2))
    table_mode: str = "replicated"  # boundary-table layout for distributed
                                    # requests ("sharded" = deviation (s))
    table_max_iter: int = 64
    # request extents: prime / non-divisible on purpose (bucketing path)
    shapes: tuple = ((96, 96, 96), (97, 61, 43), (64, 96, 48), (101, 53, 37))
    sweep_k: int = 4           # thresholds per sweep request
    # async plane (open-loop arrivals; DESIGN.md §Serve-v2)
    rate: float = 50.0         # Poisson arrival rate, requests per second
    deadline_slack: float = 0.5  # mean request deadline slack, seconds
    # overload plane (admission control / shedding; DESIGN.md §Serve-v3)
    max_queue_depth: int = 1024       # admission budget: queued work items
    max_inflight_cells: int = 256_000_000  # admission budget: queued cells
    shed_policy: str = "never"        # "never" | "late" | "hopeless"
    overload_factor: float = 4.0      # overload smoke: x sustainable rate
    overload_queue_depth: int = 24    # tight budget used by --overload runs


def full_config() -> ServeTopologyConfig:
    return ServeTopologyConfig()


def smoke_config() -> ServeTopologyConfig:
    return ServeTopologyConfig(
        name="serve-topology-smoke", max_batch=16,
        shapes=((17, 13, 11), (13, 11, 7), (16, 12, 8)), sweep_k=3,
        slot_cost_cells=4096, rate=200.0, deadline_slack=0.25,
        max_queue_depth=256, max_inflight_cells=16_000_000,
        overload_queue_depth=16)
