"""Sharded checkpointing with atomic commits, async writes, keep-last-k
retention and reshard-on-restore (elastic scaling).

Layout:  <dir>/step_<N>/  arrays.npz + manifest.json ; a checkpoint is only
visible once its directory is atomically renamed from a .tmp staging name —
a killed writer never corrupts the latest checkpoint (the fault-tolerance
contract the driver relies on).

Restore never assumes the saving mesh: arrays come back as host numpy and
are re-placed with whatever sharding the *current* mesh prescribes
(device_put with a NamedSharding) — growing or shrinking the device count
between runs (elastic scaling) is therefore free."""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import numpy as np
import jax


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out[name] = leaf
    return out, treedef


def save_pytree(path: str, tree, step: int | None = None, extra: dict | None
                = None):
    """Write pytree leaves to <path>/ atomically (stage + rename)."""
    stage = path + ".tmp"
    if os.path.exists(stage):
        shutil.rmtree(stage)
    os.makedirs(stage, exist_ok=True)
    named, _ = _flatten_with_names(tree)
    arrays = {k: np.asarray(v) for k, v in named.items()}
    np.savez(os.path.join(stage, "arrays.npz"), **arrays)
    manifest = {"step": step, "keys": sorted(arrays),
                "time": time.time(), "extra": extra or {}}
    with open(os.path.join(stage, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(stage, path)


def load_pytree(path: str, template):
    """Restore into `template`'s structure (dtypes/shapes validated).  If a
    mesh is bound via runtime.meshctx and `template` leaves are sharded,
    re-placement uses the current shardings (elastic reshard)."""
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in z.files}
    named, treedef = _flatten_with_names(template)
    leaves = []
    for name, tmpl in named.items():
        if name not in arrays:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = arrays[name]
        if tuple(a.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"shape mismatch for {name}: {a.shape} vs {np.shape(tmpl)}")
        if hasattr(tmpl, "sharding") and hasattr(tmpl, "dtype"):
            leaves.append(jax.device_put(a.astype(tmpl.dtype), tmpl.sharding))
        else:
            leaves.append(a)
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_write: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_write = async_write
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:010d}")

    def steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self):
        s = self.steps()
        return s[-1] if s else None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def save(self, step: int, tree, extra: dict | None = None):
        """Snapshot is taken synchronously (device->host copy), the file
        write overlaps the next train steps when async_write."""
        self.wait()
        named_np = jax.tree.map(lambda x: np.asarray(x), tree)

        def _write():
            save_pytree(self._step_dir(step), named_np, step, extra)
            self._gc()

        if self.async_write:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def restore(self, template, step: int | None = None):
        self.wait()
        step = step if step is not None else self.latest_step()
        if step is None:
            return None, None
        path = self._step_dir(step)
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        return load_pytree(path, template), manifest

    def _gc(self):
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
