"""Batched multi-tenant topology query engine (DESIGN.md §Serve) and the
async deadline-aware serving plane on top of it (DESIGN.md §Serve-v2).

`TopologyEngine.submit_batch` takes heterogeneous `TopologyRequest`s (mixed
shapes, mixed query kinds) and serves them through a handful of compiled
executables:

  expand   every request unbundles into uniform work items: an MS request
           becomes its two manifold directions, a threshold sweep becomes
           one CC item per threshold (the K masks come from ONE broadcast
           compare against the single field), ascending manifolds are
           flipped host-side so every manifold item runs the descending
           program (the trick `core.distributed` already uses);
  bucket   items group by padded layout — extents round up to the next
           power of two (`serve.bucketing`), so arbitrary request shapes
           collapse onto few layouts; graph items group by their mesh
           geometry (many masks / thresholds of one mesh batch together);
           adjacent layouts can merge under a cost model
           (`slot_cost_cells`, `bucketing.merge_adjacent_layouts`) when
           the modeled pad waste is cheaper than an executable slot;
  execute  one vmapped (pure) or batched-`shard_map` (distributed) call per
           bucket chunk, so compilation AND the paper's single boundary
           all_gather amortise across tenants; compiled executables live in
           a bounded LRU cache (`cache_capacity`) with hit/miss/eviction
           counters;
  restore  labels slice back to each request's real extent and label VALUES
           remap from padded-shape flat ids to real-shape flat ids, which
           makes every engine result BIT-IDENTICAL to the sequential
           `repro.topology.submit` path (pinned by tests/test_serve_engine.py
           and, across arrival orders/deadlines/retries/evictions, by
           tests/test_serve_async.py).

`AsyncTopologyEngine` adds the request plane: `submit()` returns a
`TopologyHandle` future, work items queue in a `FlushScheduler` and execute
when a bucket fills its pow2 capacity, when an admission deadline would
otherwise be missed, or on `drain()`; a failed bucket execution retries by
splitting in half so one poisoned request cannot sink its cohort; and
idempotency-key replays are served from a small result cache.

Serve-v3 (DESIGN.md §Serve-v3) adds the overload story: admission budgets
(`max_queue_depth` / `max_inflight_cells`) past which `submit()` returns an
already-failed handle carrying a typed `Overloaded` error, a `shed_policy`
that drops queued requests whose deadline is unmeetable with a typed
`DeadlineShed` error before wasting an execution on them, slack-ordered
deadline flushes, and a `SharedExecutableCache` multiple engines attach to
so replicas stop paying duplicate compiles.  Typed plane errors surface on
handles — never as exceptions out of `submit()` / `poll()` / `drain()`.

`EngineStats` aggregates requests/items/batches, executable-cache hits,
misses and evictions, pad waste, flush reasons (each bucket execution is
counted under exactly one reason, so the four flush counters always sum to
`batches`), queue depth, rejections/sheds, retries/failures, deadline hits,
and per-request latency sums.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
import math
import time
from typing import Any

import numpy as np
import jax
import jax.numpy as jnp

from ..core.connected_components import (connected_components_grid,
                                         connected_components_graph)
from ..core.ms_segmentation import descending_manifold
from ..core.steepest import graph_steepest
from ..core.pathcompress import path_compress
from ..core.distributed import (distributed_connected_components_batch,
                                distributed_manifold_batch)
from ..core.distributed_graph import (
    distributed_connected_components_graph_batch)
from ..core._table import check_converged
from ..topology import TopologyRequest, TopologyResult
from .bucketing import (bucket_shape, batch_capacity, pad_to,
                        remap_flat_labels, pad_waste, merge_adjacent_layouts)
from .compile_cache import SharedExecutableCache
from .scheduler import FlushScheduler, MonotonicClock, check_shed_policy


class PlaneError(Exception):
    """Base of the typed serving-plane errors.  These surface on handles
    (`TopologyHandle.exception()`), never as exceptions escaping `submit()`
    / `poll()` / `drain()` — callers distinguish plane decisions from real
    execution failures by this type."""


class Overloaded(PlaneError):
    """Admission refused: a budget (`max_queue_depth` /
    `max_inflight_cells`) would be exceeded.  Nothing was queued; the
    caller may retry later."""


class DeadlineShed(PlaneError):
    """The request was admitted but dropped by the shed policy because its
    deadline became unmeetable before it executed."""


@dataclasses.dataclass
class EngineStats:
    """Aggregate serving counters (host-side, monotonically increasing)."""
    requests: int = 0
    items: int = 0          # work items after expansion (ms=2, sweep=K)
    batches: int = 0        # bucket-chunk executions
    cache_hits: int = 0     # executable reused for a bucket execution
    cache_misses: int = 0   # executable compiled for a new layout key
    cache_evictions: int = 0  # executables dropped by the LRU bound
    real_cells: int = 0     # payload cells actually requested
    padded_cells: int = 0   # cells executed after layout + batch padding
    # why each bucket execution ran (exactly one reason per execution, so
    # these four always sum to `batches`)
    flush_capacity: int = 0   # bucket filled its pow2 batch capacity
    flush_deadline: int = 0   # earliest deadline would otherwise be missed
    flush_drain: int = 0      # explicit drain (sync submit_batch flushes
                              # count here: every submit_batch is an
                              # immediate drain of its own buckets)
    flush_retry: int = 0      # re-execution of a split half after a failure
    # async request plane
    retries: int = 0        # failed executions that were split and retried
    completed: int = 0      # handles resolved with a result
    failures: int = 0       # handles resolved with an exception
    dedup_hits: int = 0     # idempotency-key replays served without work
    # overload plane (DESIGN.md §Serve-v3).  `requests`/`items` count only
    # ADMITTED work, so after a drain: completed + failures + shed ==
    # requests, while rejected tracks refused submissions separately.
    rejected: int = 0       # submissions refused at admission (Overloaded)
    shed: int = 0           # admitted requests dropped by the shed policy
    queue_depth_limit: int = 0  # rejections charged to max_queue_depth
                                # (the rest hit max_inflight_cells)
    deadline_hits: int = 0     # requests completed at or before deadline
    deadline_misses: int = 0   # requests completed after their deadline
    queue_depth_peak: int = 0  # max items queued in the scheduler at once
    latency_count: int = 0     # requests with a recorded latency
    latency_sum: float = 0.0   # sum of completion - submission (clock units)

    @property
    def hit_rate(self) -> float:
        total = self.cache_hits + self.cache_misses
        return self.cache_hits / total if total else 0.0

    @property
    def pad_fraction(self) -> float:
        return (1.0 - self.real_cells / self.padded_cells
                if self.padded_cells else 0.0)

    @property
    def deadline_hit_rate(self) -> float:
        total = self.deadline_hits + self.deadline_misses
        return self.deadline_hits / total if total else 1.0

    @property
    def latency_mean(self) -> float:
        return self.latency_sum / self.latency_count if self.latency_count \
            else 0.0

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["hit_rate"] = self.hit_rate
        d["pad_fraction"] = self.pad_fraction
        d["deadline_hit_rate"] = self.deadline_hit_rate
        d["latency_mean"] = self.latency_mean
        return d


@dataclasses.dataclass
class _WorkItem:
    """One uniform unit of work after request expansion."""
    kind: str               # "cc" | "manifold" (ms and sweeps are expanded)
    domain: str
    backend: str
    payload: np.ndarray     # real-extent mask (bool) / order field (int;
                            # ascending already flipped host-side)
    connectivity: int
    gather_mask: bool
    table_mode: str         # boundary/cut table layout (deviation (s))
    table_max_iter: int
    mesh: Any               # distributed only
    decomp: Any             # distributed graph only
    senders: Any            # graph only
    receivers: Any          # graph only
    req_idx: int
    role: tuple             # ("labels",) | ("desc",) | ("asc",) |
                            # ("sweep", k)


# position of the padded layout inside a grid bucket key (see _bucket_key);
# merged buckets execute every member under the layout IN THE KEY, which may
# dominate the member's own next-pow2 layout
_GRID_LAYOUT_SLOT = 5

_FLUSH_FIELDS = {"capacity": "flush_capacity", "deadline": "flush_deadline",
                 "drain": "flush_drain", "retry": "flush_retry"}


class TopologyEngine:
    """Batched serving front-end for `TopologyRequest`s.

    min_extent:      smallest padded grid extent (bucket floor).
    max_batch:       largest batch capacity per execution; bucket
                     occupancies beyond it run in chunks.
    cache_capacity:  bound on live compiled executables (LRU eviction;
                     None disables the bound).  The default is sized so
                     repeated-layout workloads never evict — replaying a
                     workload still compiles nothing.
    slot_cost_cells: cost model for merging adjacent pow2 layouts — a
                     smaller layout folds into a dominating one when its
                     modeled extra pad cells stay below this many cells
                     (None/0 disables merging; DESIGN.md §Serve-v2).
    compile_cache:   a `SharedExecutableCache` to attach to; multiple
                     engines sharing one compile each executable exactly
                     once between them (DESIGN.md §Serve-v3).  None builds
                     a private cache of `cache_capacity` (when a shared
                     cache is passed, its own capacity governs and
                     `cache_capacity` is ignored).
    name:            owner tag for per-engine hit/miss attribution in the
                     shared cache (auto-numbered when None).
    """

    def __init__(self, min_extent: int = 8, max_batch: int = 64,
                 cache_capacity: int | None = 64,
                 slot_cost_cells: int | None = None,
                 compile_cache: SharedExecutableCache | None = None,
                 name: str | None = None):
        self.min_extent = int(min_extent)
        self.max_batch = int(max_batch)
        self.slot_cost_cells = slot_cost_cells
        self.stats = EngineStats()
        self.cache = (compile_cache if compile_cache is not None
                      else SharedExecutableCache(capacity=cache_capacity))
        self.cache_capacity = self.cache.capacity
        self._owner = self.cache.attach(name)
        self._bucket_runs: dict = {}   # exec key -> executions served

    @property
    def _exec(self):
        """The (possibly shared) executable store, exec key -> (fn,
        has_stats).  Kept as a property so pre-v3 call sites (tests,
        benchmarks) that measure `len(eng._exec)` keep working."""
        return self.cache._store

    # --- public API -----------------------------------------------------------

    def submit(self, request: TopologyRequest) -> TopologyResult:
        return self.submit_batch([request])[0]

    def submit_batch(self, requests) -> list:
        """Serve a batch of requests; results keep submission order and are
        bit-identical to `repro.topology.submit` per request."""
        for r in requests:
            r.validate()
        items = []
        for idx, req in enumerate(requests):
            items.extend(self._expand(idx, req))
        self.stats.requests += len(requests)
        self.stats.items += len(items)

        buckets: dict = {}
        for it in items:
            buckets.setdefault(self._bucket_key(it), []).append(it)
        buckets = self._merge_grid_buckets(buckets)

        outputs: dict = {}   # (req_idx, role) -> (labels np, stats or None)
        for key, group in buckets.items():
            for lo in range(0, len(group), self.max_batch):
                self._run_bucket(key, group[lo:lo + self.max_batch], outputs,
                                 reason="drain")

        return [self._assemble(idx, req, outputs)
                for idx, req in enumerate(requests)]

    def cache_info(self) -> dict:
        return {"hits": self.stats.cache_hits,
                "misses": self.stats.cache_misses,
                "evictions": self.stats.cache_evictions,
                "size": len(self._exec),
                "capacity": self.cache_capacity,
                "hit_rate": self.stats.hit_rate,
                "runs_per_executable": dict(self._bucket_runs),
                "owner": self._owner,
                "shared": self.cache.info()}

    # --- request expansion ----------------------------------------------------

    def _expand(self, idx: int, req: TopologyRequest) -> list:
        def item(kind, payload, role):
            return _WorkItem(kind=kind, domain=req.domain,
                             backend=req.backend,
                             payload=payload, connectivity=req.connectivity,
                             gather_mask=req.gather_mask,
                             table_mode=req.table_mode,
                             table_max_iter=req.table_max_iter,
                             mesh=req.mesh,
                             decomp=req.decomp, senders=req.senders,
                             receivers=req.receivers, req_idx=idx, role=role)

        if req.query in ("manifold", "ms") and (
                req.domain == "graph" and req.backend == "distributed"):
            raise NotImplementedError(
                "manifold/MS on distributed graphs needs the order-field "
                "halo through GraphDecomp's ghost layer (ROADMAP carried "
                "item)")

        if req.query == "cc":
            return [item("cc", np.asarray(req.mask, dtype=bool),
                         ("labels",))]
        if req.query == "manifold":
            order = np.asarray(req.order)
            if not req.descending:
                order = np.asarray(order.size - 1 - order, dtype=order.dtype)
            return [item("manifold", order, ("labels",))]
        if req.query == "ms":
            order = np.asarray(req.order)
            flipped = np.asarray(order.size - 1 - order, dtype=order.dtype)
            return [item("manifold", order, ("desc",)),
                    item("manifold", flipped, ("asc",))]
        # threshold_sweep: K masks from ONE broadcast compare of the single
        # field; each enters the shared cc bucket of its layout
        field = np.asarray(req.field)
        thr = np.asarray(req.thresholds).reshape(-1)
        masks = field[None] > thr.reshape((-1,) + (1,) * field.ndim)
        return [item("cc", masks[k], ("sweep", k))
                for k in range(thr.size)]

    # --- bucketing / executables ----------------------------------------------

    def _bucket_key(self, it: _WorkItem) -> tuple:
        if it.domain == "grid":
            mesh_key = (None if it.backend == "pure"
                        else (tuple(it.mesh.axis_names),
                              tuple(it.mesh.devices.shape), id(it.mesh)))
            # the layout sits at _GRID_LAYOUT_SLOT — _run_bucket pads to the
            # key's layout, not the item's, so merged buckets stay coherent
            return ("grid", it.backend, it.kind, it.connectivity,
                    it.gather_mask,
                    bucket_shape(it.payload.shape, self.min_extent),
                    mesh_key, it.table_mode, it.table_max_iter)
        if it.backend == "pure":
            # same-geometry masks batch together; the compiled executable is
            # nonetheless shared across graphs of equal (n, m) because the
            # edge lists are traced arguments (see _exec_key)
            graph_key = (it.payload.shape[0], np.asarray(it.senders).size,
                         id(it.senders), id(it.receivers))
        else:
            graph_key = (id(it.decomp), it.gather_mask, it.table_mode,
                         it.table_max_iter)
        return ("graph", it.backend, it.kind, graph_key)

    def _merge_grid_buckets(self, buckets: dict) -> dict:
        """Apply the cost-model merge plan: grid buckets that differ ONLY in
        layout fold into an adjacent dominating layout when the modeled pad
        waste is cheaper than an executable slot (bit-identical either way —
        restore remaps label values from whatever layout actually ran)."""
        if not self.slot_cost_cells:
            return buckets
        families: dict = {}   # key minus layout -> [full keys]
        for key in buckets:
            if key[0] == "grid":
                fam = key[:_GRID_LAYOUT_SLOT] + key[_GRID_LAYOUT_SLOT + 1:]
                families.setdefault(fam, []).append(key)
        for keys in families.values():
            if len(keys) < 2:
                continue
            plan = merge_adjacent_layouts(
                {k[_GRID_LAYOUT_SLOT]: len(buckets[k]) for k in keys},
                self.slot_cost_cells)
            for k in keys:
                tgt_layout = plan[k[_GRID_LAYOUT_SLOT]]
                if tgt_layout != k[_GRID_LAYOUT_SLOT]:
                    tgt = (k[:_GRID_LAYOUT_SLOT] + (tgt_layout,)
                           + k[_GRID_LAYOUT_SLOT + 1:])
                    buckets.setdefault(tgt, []).extend(buckets.pop(k))
        return buckets

    def _exec_key(self, bkey: tuple, it: _WorkItem, capacity: int) -> tuple:
        if bkey[0] == "graph" and bkey[1] == "pure":
            # drop the edge-list identity: (n, m) + dtypes determine the
            # trace, so equal-shape graphs share one executable
            bkey = bkey[:3] + ((it.payload.shape[0],
                                np.asarray(it.senders).size),)
        return bkey + (capacity, str(it.payload.dtype))

    def _get_executable(self, ekey: tuple, it0: _WorkItem):
        """Lookup-or-build through the (possibly shared) LRU cache; it
        never holds more than its capacity (evictions are counted, and an
        evicted layout simply recompiles on its next use — bit-identical,
        pinned by tests/test_serve_async.py).  Hits/misses land both on
        this engine's stats and on its attribution row in the cache."""
        built, hit, evicted = self.cache.lookup(
            ekey, lambda: self._build_executable(it0), self._owner)
        if hit:
            self.stats.cache_hits += 1
        else:
            self.stats.cache_misses += 1
        self.stats.cache_evictions += evicted
        return built

    def _build_executable(self, it: _WorkItem):
        """(callable, has_stats) for one layout bucket.  The callable takes
        the stacked padded payload (plus edge lists for pure graphs) and
        returns (labels, stats-or-None)."""
        conn, gm = it.connectivity, it.gather_mask
        tm, tmi = it.table_mode, it.table_max_iter
        if it.domain == "grid":
            if it.backend == "pure":
                if it.kind == "cc":
                    one = lambda m: connected_components_grid(m, conn).labels
                else:
                    one = lambda o: descending_manifold(o, conn)[0].reshape(
                        o.shape)
                return jax.jit(jax.vmap(one)), False
            mesh = it.mesh
            if it.kind == "cc":
                fn = lambda b: distributed_connected_components_batch(
                    b, mesh, conn, gm, table_mode=tm, table_max_iter=tmi)
            else:
                fn = lambda b: distributed_manifold_batch(
                    b, mesh, conn, descending=True, table_mode=tm,
                    table_max_iter=tmi)
            return jax.jit(fn), True
        if it.backend == "pure":
            if it.kind == "cc":
                one = lambda m, s, r: connected_components_graph(
                    m, s, r).labels
            else:
                one = lambda o, s, r: path_compress(
                    graph_steepest(o, s, r, descending=True))[0]
            return jax.jit(jax.vmap(one, in_axes=(0, None, None))), False
        decomp, mesh = it.decomp, it.mesh
        fn = lambda b: distributed_connected_components_graph_batch(
            b, decomp, mesh, gm, table_mode=tm, table_max_iter=tmi)
        return jax.jit(fn), True

    # --- execution ------------------------------------------------------------

    def _execute(self, fn, group, args):
        """The execution seam: every compiled-executable invocation funnels
        through here so fault-injection tests can monkeypatch it (group is
        passed for observability — chosen-request poisoning)."""
        return fn(*args)

    def _run_bucket(self, bkey: tuple, group: list, outputs: dict,
                    reason: str = "drain") -> None:
        it0 = group[0]
        capacity = batch_capacity(len(group), self.max_batch)
        ekey = self._exec_key(bkey, it0, capacity)
        fn, has_stats = self._get_executable(ekey, it0)
        self._bucket_runs[ekey] = self._bucket_runs.get(ekey, 0) + 1
        self.stats.batches += 1
        # exactly one flush reason per execution (counted BEFORE the call,
        # so the reason sum tracks `batches` even when the execution fails)
        field = _FLUSH_FIELDS[reason]
        setattr(self.stats, field, getattr(self.stats, field) + 1)

        if it0.domain == "grid":
            padded = bkey[_GRID_LAYOUT_SLOT]
            fill = False if it0.kind == "cc" else -1
            stack = np.stack(
                [pad_to(np.asarray(g.payload), padded, fill)
                 for g in group]
                + [np.full(padded, fill, dtype=it0.payload.dtype)]
                * (capacity - len(group)))
            real, padded_cells = pad_waste(
                [g.payload.shape for g in group], padded, capacity)
        else:
            padded = it0.payload.shape          # graphs never pad the extent
            fill = False if it0.kind == "cc" else -1
            stack = np.stack(
                [np.asarray(g.payload) for g in group]
                + [np.full(padded, fill, dtype=it0.payload.dtype)]
                * (capacity - len(group)))
            real, padded_cells = pad_waste(
                [g.payload.shape for g in group], padded, capacity)
        self.stats.real_cells += real
        self.stats.padded_cells += padded_cells

        if it0.domain == "graph" and it0.backend == "pure":
            out = self._execute(fn, group,
                                (jnp.asarray(stack), jnp.asarray(it0.senders),
                                 jnp.asarray(it0.receivers)))
        else:
            out = self._execute(fn, group, (jnp.asarray(stack),))
        labels, stats = out if has_stats else (out, None)
        labels = np.asarray(jax.block_until_ready(labels))

        # the executables run under jit, where check_converged is a no-op
        # (tracers cannot be inspected), so a too-small table_max_iter
        # would silently hand back mid-chain labels; re-check host-side on
        # the materialized per-slot flags (only real slots — pad slots may
        # legitimately not converge).  Raising here composes with the async
        # split-retry: the bisection isolates exactly the non-converged
        # requests onto their own handles.
        if stats is not None and "converged" in getattr(stats, "_fields", ()):
            check_converged(np.asarray(stats.converged)[:len(group)],
                            "boundary table resolution (serve bucket "
                            f"{bkey[1]}/{it0.kind})", it0.table_max_iter)

        for pos, g in enumerate(group):
            lab = (remap_flat_labels(labels[pos], padded, g.payload.shape)
                   if g.domain == "grid" else labels[pos])
            st = (None if stats is None else
                  {f: np.asarray(v)[pos].item()
                   for f, v in zip(stats._fields, stats)})
            outputs[(g.req_idx, g.role)] = (lab, st)

    # --- result assembly ------------------------------------------------------

    def _assemble(self, idx: int, req: TopologyRequest,
                  outputs: dict) -> TopologyResult:
        if req.query in ("cc", "manifold"):
            lab, st = outputs[(idx, ("labels",))]
            return TopologyResult(req.query, labels=jnp.asarray(lab),
                                  stats=st, tag=req.tag)
        if req.query == "ms":
            desc, st_d = outputs[(idx, ("desc",))]
            asc, st_a = outputs[(idx, ("asc",))]
            n = math.prod(desc.shape)
            dt = np.int64 if jax.config.jax_enable_x64 else np.int32
            seg = desc.astype(dt) * dt(n) + asc.astype(dt)
            stats = (None if st_d is None
                     else {"descending": st_d, "ascending": st_a})
            return TopologyResult("ms", ascending=jnp.asarray(asc),
                                  descending=jnp.asarray(desc),
                                  segmentation=jnp.asarray(seg),
                                  stats=stats, tag=req.tag)
        # threshold_sweep
        thr = np.asarray(req.thresholds).reshape(-1)
        labs, sts = [], []
        for k in range(thr.size):
            lab, st = outputs[(idx, ("sweep", k))]
            labs.append(lab)
            sts.append(st)
        stats = (None if sts[0] is None else
                 {f: [s[f] for s in sts] for f in sts[0]})
        return TopologyResult("threshold_sweep",
                              labels=jnp.asarray(np.stack(labs)),
                              stats=stats, tag=req.tag)


# --- async request plane (DESIGN.md §Serve-v2) --------------------------------


class TopologyHandle:
    """Future-like handle for one async request.

    The serving plane is cooperative (single-threaded): `result()` on a
    pending handle drains the engine — deterministic, and bit-identical to
    whatever a later flush would have produced anyway."""

    __slots__ = ("request", "deadline", "idempotency_key", "submitted_at",
                 "completed_at", "_engine", "_result", "_exc", "_done")

    def __init__(self, engine, request, deadline=None, idempotency_key=None):
        self.request = request
        self.deadline = deadline
        self.idempotency_key = idempotency_key
        self.submitted_at = None
        self.completed_at = None
        self._engine = engine
        self._result = None
        self._exc = None
        self._done = False

    def done(self) -> bool:
        return self._done

    def exception(self):
        """The exception this handle surfaced, or None (does not force a
        flush; pending handles return None)."""
        return self._exc

    def result(self) -> TopologyResult:
        if not self._done:
            self._engine.drain()
        if self._exc is not None:
            raise self._exc
        return self._result


@dataclasses.dataclass
class _Pending:
    """Book-keeping for one in-flight async request."""
    handle: TopologyHandle
    request: TopologyRequest
    need: set               # roles still expected in the outputs dict


class AsyncTopologyEngine(TopologyEngine):
    """Deadline-aware async front-end over the batched engine.

    `submit()` enqueues and returns a `TopologyHandle`; buckets flush when
    they fill their pow2 capacity, when `poll()`/`advance()` finds an
    admission deadline that would otherwise be missed (deadline minus the
    scheduler's measured per-layout execute estimate), or on `drain()`.
    Results are bit-identical to sequential `repro.topology.submit`
    regardless of arrival order, flush timing, retries, or cache evictions.

    clock:  time source for deadlines/latencies — `MonotonicClock` by
            default, a `VirtualClock` for deterministic tests.
    default_estimate:  cold-start execute estimate for never-measured
            buckets; None picks `scheduler.COLD_START_ESTIMATE` (an
            explicit 0.0 restores "flush exactly at the deadline").
    charge_execution_time:  advance a virtual clock by the measured wall
            duration of each execution (virtual-time open-loop benchmarks).
    result_cache_capacity:  LRU bound on cached idempotency-key results.
    max_queue_depth:  admission budget on queued work items; a submission
            that would exceed it returns a rejected handle with a typed
            `Overloaded` error (None = unbounded, the pre-v3 behavior).
    max_inflight_cells:  admission budget on queued payload cells (the
            memory-shaped analogue of queue depth; None = unbounded).
    shed_policy:  "never" (default) keeps every admitted request;
            "late" sheds queued requests whose deadline already passed;
            "hopeless" also sheds those the execute estimate says cannot
            finish in time.  Shed handles fail with `DeadlineShed`.
    """

    def __init__(self, min_extent: int = 8, max_batch: int = 64,
                 cache_capacity: int | None = 64,
                 slot_cost_cells: int | None = None, clock=None,
                 default_estimate: float | None = None,
                 charge_execution_time: bool = False,
                 result_cache_capacity: int = 256,
                 max_queue_depth: int | None = None,
                 max_inflight_cells: int | None = None,
                 shed_policy: str = "never",
                 compile_cache: SharedExecutableCache | None = None,
                 name: str | None = None):
        super().__init__(min_extent=min_extent, max_batch=max_batch,
                         cache_capacity=cache_capacity,
                         slot_cost_cells=slot_cost_cells,
                         compile_cache=compile_cache, name=name)
        self.clock = clock if clock is not None else MonotonicClock()
        self.scheduler = FlushScheduler(capacity=self.max_batch,
                                        clock=self.clock,
                                        default_estimate=default_estimate)
        self._charge = (bool(charge_execution_time)
                        and hasattr(self.clock, "advance"))
        self.result_cache_capacity = int(result_cache_capacity)
        self.max_queue_depth = (None if max_queue_depth is None
                                else int(max_queue_depth))
        self.max_inflight_cells = (None if max_inflight_cells is None
                                   else int(max_inflight_cells))
        self.shed_policy = check_shed_policy(shed_policy)
        self._inflight_cells = 0    # payload cells currently queued
        self._rid = itertools.count()
        self._pending: dict = {}    # rid -> _Pending
        self._outputs: dict = {}    # (rid, role) -> (labels, stats)
        self._inflight: dict = {}   # idempotency key -> pending handle
        self._results = collections.OrderedDict()  # idem key -> result (LRU)
        self.latencies: list = []   # per-request latency, clock units

    # --- admission ------------------------------------------------------------

    def submit(self, request: TopologyRequest, deadline: float | None = None,
               idempotency_key=None) -> TopologyHandle:
        """Enqueue one request; returns a handle (NOT a result — use
        `submit_batch` for the synchronous path).  `deadline` is an absolute
        clock time the request should complete by; `idempotency_key` replays
        are deduplicated against in-flight requests and a bounded result
        cache without executing anything.  Past an admission budget the
        handle comes back already failed with `Overloaded` — submit never
        raises for overload (typed plane errors stay on handles)."""
        request.validate()
        if idempotency_key is not None:
            # dedup before admission: replays cost no queue space, so they
            # are served even when the plane is refusing new work
            cached = self._results.get(idempotency_key)
            if cached is not None:
                self.stats.dedup_hits += 1
                self._results.move_to_end(idempotency_key)
                h = TopologyHandle(self, request, deadline, idempotency_key)
                h.submitted_at = h.completed_at = self.clock.now()
                h._result, h._done = cached, True
                return h
            if idempotency_key in self._inflight:
                self.stats.dedup_hits += 1
                return self._inflight[idempotency_key]

        rid = next(self._rid)
        items = self._expand(rid, request)
        refusal = self._admission_error(items)
        if refusal is not None:
            # rejected: nothing queued, no rid book-keeping, not counted
            # in requests/items — the handle carries the typed error
            self.stats.rejected += 1
            h = TopologyHandle(self, request, deadline, idempotency_key)
            h.submitted_at = h.completed_at = self.clock.now()
            h._exc, h._done = refusal, True
            return h

        handle = TopologyHandle(self, request, deadline, idempotency_key)
        handle.submitted_at = self.clock.now()
        self.stats.requests += 1
        self.stats.items += len(items)
        self._pending[rid] = _Pending(handle, request,
                                      {it.role for it in items})
        if idempotency_key is not None:
            self._inflight[idempotency_key] = handle
        for it in items:
            self.scheduler.enqueue(self._bucket_key(it), it, deadline)
            self._inflight_cells += int(it.payload.size)
        self.stats.queue_depth_peak = max(self.stats.queue_depth_peak,
                                          self.scheduler.depth())
        self._shed_pass()   # a hopeless submission sheds before any flush
        for key in self.scheduler.full():
            self._flush(key, "capacity")
        self.poll()
        return handle

    def _admission_error(self, items) -> Overloaded | None:
        """The typed refusal this submission would get, or None to admit."""
        if self.max_queue_depth is not None:
            depth = self.scheduler.depth()
            if depth + len(items) > self.max_queue_depth:
                self.stats.queue_depth_limit += 1
                return Overloaded(
                    f"queue depth {depth} + {len(items)} items would exceed "
                    f"max_queue_depth={self.max_queue_depth}")
        if self.max_inflight_cells is not None:
            cells = sum(int(it.payload.size) for it in items)
            if self._inflight_cells + cells > self.max_inflight_cells:
                return Overloaded(
                    f"queued payload {self._inflight_cells} + {cells} cells "
                    f"would exceed max_inflight_cells="
                    f"{self.max_inflight_cells}")
        return None

    # --- flush triggers -------------------------------------------------------

    def poll(self) -> int:
        """Shed what the policy says is unmeetable, then flush every bucket
        whose earliest deadline would be missed by waiting longer (in slack
        order — most overdue first); returns the number of buckets flushed.
        Call after time passes (a `VirtualClock` advance, or periodically
        on a real clock)."""
        self._shed_pass()
        flushed = 0
        for key in self.scheduler.due():
            self._flush(key, "deadline")
            flushed += 1
        return flushed

    def advance(self, dt: float) -> int:
        """Virtual-clock convenience: advance time, then poll."""
        self.clock.advance(dt)
        return self.poll()

    def drain(self) -> None:
        """Flush everything queued (end of a burst / shutdown).  Drain is
        the one flush with a global view, so the cost-model layout merge
        applies here (capacity/deadline flushes act on single buckets)."""
        self._shed_pass()
        popped = self.scheduler.pop_all()
        self._uncharge(e for v in popped.values() for e in v)
        buckets = {k: [e.item for e in v] for k, v in popped.items()}
        buckets = self._merge_grid_buckets(buckets)
        for key, group in buckets.items():
            self._execute_group(key, group, "drain")

    def pending(self) -> int:
        """Requests admitted but not yet resolved."""
        return len(self._pending)

    # --- load shedding --------------------------------------------------------

    def _uncharge(self, entries) -> None:
        """Release the inflight-cells admission budget for entries leaving
        the queue (flush, drain, shed, or sibling purge)."""
        for e in entries:
            self._inflight_cells -= int(e.item.payload.size)

    def _shed_pass(self) -> int:
        """Apply the shed policy: drop queued entries whose deadline is
        unmeetable, fail their requests with a typed `DeadlineShed`, and
        purge each shed request's sibling items from other buckets so no
        execution is wasted on a request that can no longer succeed.
        Returns the number of requests shed."""
        if self.shed_policy == "never":
            return 0
        dropped = self.scheduler.shed(self.shed_policy)
        if not dropped:
            return 0
        self._uncharge(e for _, e in dropped)
        now = self.clock.now()
        by_rid: dict = {}
        for key, e in dropped:
            by_rid.setdefault(e.item.req_idx, (key, e))
        n = 0
        for rid in sorted(by_rid):
            key, e = by_rid[rid]
            self._uncharge(self.scheduler.purge(
                lambda it, rid=rid: it.req_idx == rid))
            exc = DeadlineShed(
                f"deadline {e.deadline:.6f} unmeetable at t={now:.6f} "
                f"(bucket estimate {self.scheduler.estimate(key):.6f}s, "
                f"shed_policy={self.shed_policy!r})")
            self._fail_request(rid, exc, counter="shed")
            n += 1
        return n

    # --- execution with split-retry -------------------------------------------

    def _flush(self, key, reason: str) -> None:
        entries = self.scheduler.pop(key)
        self._uncharge(entries)
        group = [e.item for e in entries]
        if group:
            self._execute_group(key, group, reason)

    def _execute_group(self, key, group: list, reason: str) -> None:
        for lo in range(0, len(group), self.max_batch):
            self._run_resilient(key, group[lo:lo + self.max_batch], reason)
        self._settle(group)

    def _run_resilient(self, key, chunk: list, reason: str) -> None:
        """Run one bucket chunk; on failure retry by splitting in half, so
        a poisoned request bisects down to a singleton and surfaces its
        exception on its own handle while every cohort member re-batches
        and completes."""
        t0 = self.clock.now()
        w0 = time.perf_counter()
        try:
            self._run_bucket(key, chunk, self._outputs, reason)
        except Exception as exc:                       # noqa: BLE001
            if len(chunk) == 1:
                self._fail(chunk[0], exc)
                return
            self.stats.retries += 1
            half = len(chunk) // 2
            self._run_resilient(key, chunk[:half], "retry")
            self._run_resilient(key, chunk[half:], "retry")
            return
        if self._charge:
            self.clock.advance(time.perf_counter() - w0)
        self.scheduler.observe(key, self.clock.now() - t0)

    # --- completion -----------------------------------------------------------

    def _settle(self, group: list) -> None:
        """Resolve every request whose outputs are now complete; outputs of
        already-resolved (failed) requests are dropped."""
        for rid in sorted({it.req_idx for it in group}):
            rec = self._pending.get(rid)
            if rec is None:
                for it in group:
                    if it.req_idx == rid:
                        self._outputs.pop((rid, it.role), None)
                continue
            if all((rid, role) in self._outputs for role in rec.need):
                result = self._assemble(rid, rec.request, self._outputs)
                for role in rec.need:
                    del self._outputs[(rid, role)]
                del self._pending[rid]
                self._resolve(rec.handle, result)

    def _resolve(self, handle: TopologyHandle, result: TopologyResult):
        now = self.clock.now()
        handle._result, handle._done = result, True
        handle.completed_at = now
        lat = now - handle.submitted_at
        self.latencies.append(lat)
        self.stats.completed += 1
        self.stats.latency_count += 1
        self.stats.latency_sum += lat
        if handle.deadline is not None:
            if now <= handle.deadline:
                self.stats.deadline_hits += 1
            else:
                self.stats.deadline_misses += 1
        if handle.idempotency_key is not None:
            self._inflight.pop(handle.idempotency_key, None)
            self._results[handle.idempotency_key] = result
            self._results.move_to_end(handle.idempotency_key)
            while len(self._results) > self.result_cache_capacity:
                self._results.popitem(last=False)

    def _fail(self, item: _WorkItem, exc: BaseException) -> None:
        self._fail_request(item.req_idx, exc)

    def _fail_request(self, rid: int, exc: BaseException,
                      counter: str = "failures") -> None:
        """Resolve a request's handle with an exception, charged to the
        given stats counter ("failures" for execution errors, "shed" for
        policy drops)."""
        rec = self._pending.pop(rid, None)
        if rec is None or rec.handle._done:
            return
        rec.handle._exc, rec.handle._done = exc, True
        rec.handle.completed_at = self.clock.now()
        setattr(self.stats, counter, getattr(self.stats, counter) + 1)
        for role in rec.need:   # drop any sibling outputs already produced
            self._outputs.pop((rid, role), None)
        if rec.handle.idempotency_key is not None:
            # failures are never cached: a replayed key re-executes
            self._inflight.pop(rec.handle.idempotency_key, None)
