"""MS segmentation vs the brute-force steepest-path oracle (paper §3.3)."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import (ms_segmentation, ms_segmentation_graph, compute_order,
                        descending_manifold, ascending_manifold, extrema)
from repro.data import perlin_noise
from oracles import oracle_manifold, grid_neighbors


@pytest.mark.parametrize("shape,conn", [
    ((12, 13), 4), ((12, 13), 6),
    ((7, 8, 9), 6), ((7, 8, 9), 14),
])
def test_manifolds_match_oracle(shape, conn):
    rng = np.random.default_rng(0)
    order = np.asarray(
        compute_order(jnp.asarray(rng.standard_normal(shape))))
    desc, _ = descending_manifold(jnp.asarray(order), conn)
    asc, _ = ascending_manifold(jnp.asarray(order), conn)
    np.testing.assert_array_equal(
        np.asarray(desc).reshape(shape), oracle_manifold(order, conn, True))
    np.testing.assert_array_equal(
        np.asarray(asc).reshape(shape), oracle_manifold(order, conn, False))


def test_perlin_segmentation():
    field = perlin_noise((24, 24, 24), frequency=0.15, seed=3)
    order = compute_order(jnp.asarray(field))
    seg = ms_segmentation(order, connectivity=6)
    # segmentation labels are consistent hashes of (desc, asc)
    n = order.size
    expect = (np.asarray(seg.descending).astype(np.int32) * n
              + np.asarray(seg.ascending))
    np.testing.assert_array_equal(np.asarray(seg.segmentation), expect)
    # every desc label is a maximum, every asc label a minimum
    maxima, minima = extrema(order, 6)
    assert np.asarray(maxima).ravel()[np.unique(np.asarray(seg.descending))].all()
    assert np.asarray(minima).ravel()[np.unique(np.asarray(seg.ascending))].all()


def test_graph_variant_matches_grid():
    """Unstructured DPC on the grid's edge list == structured DPC."""
    shape, conn = (9, 10), 6
    rng = np.random.default_rng(1)
    order = np.asarray(compute_order(jnp.asarray(rng.standard_normal(shape))))
    send, recv = grid_neighbors(shape, conn)
    seg_graph = ms_segmentation_graph(
        jnp.asarray(order.ravel()), jnp.asarray(send), jnp.asarray(recv))
    seg_grid = ms_segmentation(jnp.asarray(order), conn)
    np.testing.assert_array_equal(
        np.asarray(seg_graph.descending),
        np.asarray(seg_grid.descending).ravel())
    np.testing.assert_array_equal(
        np.asarray(seg_graph.ascending),
        np.asarray(seg_grid.ascending).ravel())


def test_monotone_field_single_segment():
    order = jnp.arange(5 * 6, dtype=jnp.int32).reshape(5, 6)
    seg = ms_segmentation(order, connectivity=4)
    assert np.unique(np.asarray(seg.descending)).size == 1
    assert np.unique(np.asarray(seg.ascending)).size == 1
    assert int(np.asarray(seg.descending)[0, 0]) == 5 * 6 - 1
    assert int(np.asarray(seg.ascending)[0, 0]) == 0
