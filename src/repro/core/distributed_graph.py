"""Distributed connected components on unstructured (edge-list) meshes.

The paper computes CC "in distributed structured and unstructured grids,
based either on the connectivity of the underlying mesh or a feature mask"
(paper §5); `distributed.py` covers the structured block lattice — this
module covers the unstructured side with the same phase structure, swapping
coordinate arithmetic for *table-driven* id maps:

  decomposition  GraphDecomp vertex-partitions a global edge list into
                 per-device local subgraphs plus a one-ring ghost layer
                 (the unstructured analog of BlockDecomp's ghost faces);
                 every global<->local id translation is a precomputed
                 lookup table instead of stride arithmetic.
  local phase    graph steepest-init (graph_mask_argmax with masked ghosts
                 pinned to self, Alg. 1 lines 6-8) + path compression +
                 the stitch fixpoint (Alg. 3, deviation (d) in DESIGN.md)
                 run entirely device-local — no collectives.
  ONE comm phase lax.all_gather of every partition's owned *cut* vertices
                 (owned vertices incident to an inter-partition edge) into
                 a replicated flat table; labels and the cut-vertex masks
                 ride the same gather (deviation (b) in DESIGN.md).
  resolution     pointer chase over the table (Alg. 2 lines 15-25, slot
                 lookup by sorted-gid search), then the hook+propagate
                 fixpoint over the static cut-edge list and equal-label
                 groups (deviation (d2) in DESIGN.md), then value-search
                 substitution — all shared with the block backend via
                 core/_table.py, executed identically on every device.

Ghost *input* values (the mask at ghost vertices) are materialised by the
input scatter `mask[local_gid]` rather than exchanged with ppermute — the
unstructured analog of the structured halo; see deviation (g1) in DESIGN.md.
Fixed SPMD shapes are obtained by padding: the ghost/edge/cut tables pad to
their maxima (deviation (g2) in DESIGN.md), and each partition's owned set
pads to `max(counts)` with inert sentinel slots (deviation (p)), so
*imbalanced* (METIS-style) partitions — and vertex counts that do not
divide the partition count — are first-class.

`GraphDPCStats.comm_phases` counts the all_gather phases actually traced
into the program (the paper's budget: exactly one).
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ._shardmap import shard_map_norep
from ._table import (pointer_chase, make_group_max, hook_propagate,
                     value_substitute)
from .stats import GraphDPCStats
from .steepest import graph_mask_argmax
from .connected_components import _cc_fixpoint, _graph_stitch

_N_STATS = len(GraphDPCStats._fields)


class GraphDecomp:
    """Static geometry of a vertex partition of an edge-list mesh.

    The mirror of BlockDecomp for unstructured meshes: where BlockDecomp
    derives ghost faces and boundary-table slots from coordinate strides,
    GraphDecomp precomputes them as numpy lookup tables from the concrete
    edge list (senders/receivers carry BOTH directions of every undirected
    edge, the repo-wide graph convention).

    Partition: `part[v]` assigns vertex v to one of `nparts` devices;
    default is contiguous blocks of global ids (the leading blocks one
    larger when ``n % nparts != 0``).  ANY explicit assignment works —
    imbalanced counts, empty partitions, a future METIS partitioner: each
    partition's owned set is padded to ``n_owned = max(counts)`` with inert
    sentinel slots (deviation (p) in DESIGN.md), the same fixed-SPMD-shape
    mechanism the ghost/edge/cut tables already use (deviation (g2)).

    Per partition p:
      owned    the sorted global ids with part == p (padded to `n_owned`;
               pad entries carry gid `n`, dropped by the output scatter);
      ghosts   the one-ring: vertices of other partitions reached by a cut
               edge from p;
      local id index into sorted(owned ∪ ghosts), padded at the end to
               `n_local`.  Sorting by *global* id preserves the invariant
               the id-maximum arguments rely on (as the block backend's
               raveled blocks do implicitly): the local id order is exactly
               the global id order restricted to the local set, so local
               argmax/stitch maxima transfer verbatim to global ids;
      edges    every directed global edge with >= 1 endpoint owned by p,
               rewritten to local ids (padded with (0, 0) self-loops, which
               are no-ops for argmax and stitch);
      cut      owned vertices incident to an inter-partition edge; cut j of
               p owns slot ``p * c_max + j`` of the gathered table.

    Ids use int32 below 2**31 vertices and int64 above (requires
    `jax_enable_x64`, mirroring BlockDecomp's refusal to wrap silently).
    """

    def __init__(self, n_vertices, senders, receivers, nparts, part=None):
        self.n = int(n_vertices)
        self.nparts = int(nparts)
        if self.n < 1:
            raise ValueError("graph must have at least one vertex")
        if self.nparts < 1:
            raise ValueError("nparts must be >= 1")
        if self.n < 2**31:
            self.id_dtype = jnp.int32
        elif jax.config.jax_enable_x64:
            self.id_dtype = jnp.int64
        else:
            # without x64, jnp silently downcasts int64 -> int32 and global
            # ids past 2**31 would wrap negative; refuse instead
            raise ValueError(
                f"graph has {self.n} >= 2**31 vertices; the int64 id path "
                "requires jax_enable_x64")
        s = np.asarray(senders, dtype=np.int64).ravel()
        r = np.asarray(receivers, dtype=np.int64).ravel()
        if s.shape != r.shape:
            raise ValueError("senders and receivers must have equal length")
        if s.size and not (0 <= s.min() and s.max() < self.n
                           and 0 <= r.min() and r.max() < self.n):
            raise ValueError("edge endpoints out of range")
        if part is None:
            # contiguous blocks; when n is not divisible the leading
            # n % nparts blocks are one vertex larger (no rounding of the
            # requested size — raggedness is padded away below)
            sizes = [len(c) for c in
                     np.array_split(np.arange(self.n), self.nparts)]
            part = np.repeat(np.arange(self.nparts), sizes)
        part = np.asarray(part, dtype=np.int64).ravel()
        if part.shape[0] != self.n:
            raise ValueError("part must assign every vertex")
        if part.size and (part.min() < 0 or part.max() >= self.nparts):
            raise ValueError(f"part values must lie in [0, {self.nparts})")
        counts = np.bincount(part, minlength=self.nparts)
        # no balance requirement: every partition's owned set pads to the
        # maximum count with inert sentinel slots (deviation (p) in
        # DESIGN.md), so arbitrary METIS-style assignments are accepted
        self.part = part
        self.owned_counts = counts
        self.n_owned = int(counts.max())
        self.pad_fraction = 1.0 - self.n / (self.nparts * self.n_owned)

        ps, pr = part[s], part[r]
        cross = ps != pr
        owned, ghosts, cut = [], [], []
        for p in range(self.nparts):
            owned.append(np.flatnonzero(part == p))
            sel = (ps == p) & cross
            ghosts.append(np.unique(r[sel]))
            cut.append(np.unique(s[sel]))
        self.g_max = max((len(g) for g in ghosts), default=0)
        self.n_local = self.n_owned + self.g_max
        if self.n_local >= 2**31:
            raise ValueError("per-partition extent exceeds int32 local ids; "
                             "use more partitions")
        self.c_max = max((len(c) for c in cut), default=0)
        self.table_size = self.nparts * self.c_max
        self.n_cut = int(sum(len(c) for c in cut))  # real (non-pad) slots

        # owned set padded to n_owned; pad gids are the out-of-range `n`,
        # which the output scatter drops (deviation (p) in DESIGN.md)
        self.owned_gid = np.full((self.nparts, self.n_owned), self.n,
                                 np.int64)
        lgid = np.full((self.nparts, self.n_local), -1, np.int64)
        valid = np.zeros((self.nparts, self.n_local), bool)
        is_ghost = np.zeros((self.nparts, self.n_local), bool)
        owned_lidx = np.zeros((self.nparts, self.n_owned), np.int32)
        cut_lidx = np.full((self.nparts, self.c_max), -1, np.int32)
        slot_of = np.full(self.n, -1, np.int64)
        gid2lid = np.full(self.n, -1, np.int64)              # reused scratch
        eloc = []
        for p in range(self.nparts):
            o, g, c = owned[p], ghosts[p], cut[p]
            self.owned_gid[p, :len(o)] = o
            loc = np.sort(np.concatenate([o, g]))  # local order == gid order
            lgid[p, :len(loc)] = loc
            valid[p, :len(loc)] = True
            gid2lid[loc] = np.arange(len(loc))
            is_ghost[p, gid2lid[g]] = True
            owned_lidx[p, :len(o)] = gid2lid[o]
            if len(o) < self.n_owned:
                # pad owned slots point at the first invalid local slot
                # (len(o) < n_owned implies len(loc) < n_local): mask False
                # there, so the pad label is -1 everywhere downstream
                owned_lidx[p, len(o):] = min(len(loc), self.n_local - 1)
            cut_lidx[p, :len(c)] = gid2lid[c]
            slot_of[c] = p * self.c_max + np.arange(len(c))
            esel = (ps == p) | (pr == p)
            ls, lr = gid2lid[s[esel]], gid2lid[r[esel]]
            if ls.size and ((ls < 0).any() or (lr < 0).any()):
                # reachable when a cross-partition edge appears in only one
                # direction: the receiving side then lacks the ghost
                raise ValueError(
                    "edge list must contain BOTH directions of every "
                    "undirected edge (one-ring ghost closure violated)")
            eloc.append((ls, lr))
            gid2lid[loc] = -1
        self.e_max = max((len(ls) for ls, _ in eloc), default=0)
        self.edge_src = np.zeros((self.nparts, self.e_max), np.int32)
        self.edge_dst = np.zeros((self.nparts, self.e_max), np.int32)
        for p, (ls, lr) in enumerate(eloc):
            self.edge_src[p, :len(ls)] = ls
            self.edge_dst[p, :len(lr)] = lr
        self.local_gid, self.local_valid = lgid, valid
        self.local_ghost = is_ghost
        self.owned_lidx = owned_lidx
        self.cut_lidx = cut_lidx

        # cut edges in table-slot space (both directions already present)
        self.cut_edge_src = slot_of[s[cross]].astype(np.int32)
        self.cut_edge_dst = slot_of[r[cross]].astype(np.int32)
        # sorted gid -> slot lookup for the pointer chase (the table-driven
        # stand-in for BlockDecomp.boundary_pos)
        allcut = np.concatenate(cut)
        order = np.argsort(allcut)
        self.cut_gid_sorted = allcut[order]
        self.cut_slot_sorted = slot_of[allcut[order]].astype(np.int32)


def _slot_lookup(dec: GraphDecomp):
    """(values -> (hit, slot)) via the sorted cut-gid table."""
    sg = jnp.asarray(dec.cut_gid_sorted, dtype=dec.id_dtype)
    sl = jnp.asarray(dec.cut_slot_sorted)

    def lookup(v):
        i = jnp.clip(jnp.searchsorted(sg, jnp.clip(v, 0)), 0, sg.size - 1)
        hit = (v >= 0) & (sg[i] == jnp.clip(v, 0))
        return hit, sl[i]

    return lookup


def _cc_partition(local_mask, lgid, local_ghost, owned_lidx, es, er,
                  cut_lidx, *, dec: GraphDecomp, name: str,
                  gather_mask: bool):
    """One partition's program (runs under shard_map; leading axis is the
    singleton shard dim)."""
    m = local_mask[0]
    gid = lgid[0]
    ghost = local_ghost[0]
    ol = owned_lidx[0]
    s, r = es[0], er[0]
    cl = cut_lidx[0]
    dt = dec.id_dtype

    # 1.+2. init: largest masked neighbor id; masked ghosts pretend self
    d0 = graph_mask_argmax(m, s, r, ghost=ghost)

    # 3. local CC fixpoint (stitch + compress, Alg. 3) in local ids
    res = _cc_fixpoint(d0, lambda d: _graph_stitch(d, m, s, r, dec.n_local))

    # 4. to global ids
    dg = jnp.where(res.labels >= 0, gid[jnp.clip(res.labels, 0)], dt(-1))
    owned = dg[ol]

    n_gather = 0
    if dec.table_size == 0:
        # no inter-partition edges (or a single partition): fully local
        final = owned
        table_iters = jnp.int32(0)
        ghost_bytes = jnp.float32(0.0)
        masked_frac = jnp.float32(0.0)
    else:
        # 5. the ONE communication phase: owned cut labels (+ masks in the
        #    same gather; gather_mask=False derives M = T >= 0 instead,
        #    DESIGN.md §Perf)
        cvalid = cl >= 0
        cli = jnp.clip(cl, 0)
        cut_lab = jnp.where(cvalid, dg[cli], dt(-1))
        if gather_mask:
            cut_m = jnp.where(cvalid, m[cli], False)
            payload = jnp.stack([cut_lab, cut_m.astype(dt)])
        else:
            payload = cut_lab[None]
        g = lax.all_gather(payload, name)        # (nparts, rows, c_max)
        n_gather += 1
        T = g[:, 0, :].reshape(-1)
        M = (g[:, 1, :].reshape(-1) != 0) if gather_mask else (T >= 0)

        # 6a. positional chase (Alg. 2 lines 15-25, table-driven lookup)
        slot_lookup = _slot_lookup(dec)

        def chase_lookup(t):
            hit, slot = slot_lookup(t)
            return jnp.where(hit, t[jnp.clip(slot, 0, t.size - 1)], t)

        Tstar, chase_iters = pointer_chase(T, chase_lookup)

        # 6b. hook + propagate over the static cut-edge list (deviation (d2))
        group_max, perm, sorted_vals = make_group_max(Tstar)
        ces = jnp.asarray(dec.cut_edge_src)
        ced = jnp.asarray(dec.cut_edge_dst)

        def cut_max(L):
            ok = M[ces] & M[ced]
            tgt = jnp.where(ok, ces, L.size)
            return L.at[tgt].max(jnp.where(ok, L[ced], dt(-1)), mode="drop")

        G, prop_iters = hook_propagate(Tstar, cut_max, group_max)

        # 7. substitution: chase own label once, adopt its group's maximum
        hit, slot = slot_lookup(owned)
        chased = jnp.where(hit, Tstar[jnp.clip(slot, 0, Tstar.size - 1)],
                           owned)
        final = value_substitute(owned, chased, sorted_vals, G[perm])
        table_iters = chase_iters + prop_iters
        rows = 2 if gather_mask else 1
        # pad cut slots (cut_lidx == -1) carry label -1 / mask False and are
        # excluded from the exchange accounting (deviation (p) in DESIGN.md)
        ghost_bytes = jnp.float32(dec.n_cut * rows * jnp.dtype(dt).itemsize)
        masked_frac = (jnp.sum(M).astype(jnp.float32)
                       / jnp.float32(max(dec.n_cut, 1)))

    stats = GraphDPCStats(
        local_iters=lax.pmax(res.n_compress_iter, name),
        table_iters=table_iters,   # identical on all devices (same table)
        stitch_rounds=lax.pmax(res.n_rounds, name),
        ghost_bytes=ghost_bytes,
        masked_ghost_fraction=masked_frac,
        comm_phases=jnp.int32(n_gather),
        pad_fraction=jnp.float32(dec.pad_fraction),
        kernel_rounds=jnp.int32(0),        # no fused grid kernel on graphs
        global_iters_saved=jnp.int32(0),
    )
    return final[None], stats


def distributed_connected_components_graph(mask, decomp: GraphDecomp,
                                           mesh: Mesh,
                                           gather_mask: bool = True):
    """Mask-implicit connected components of a vertex-partitioned edge-list
    mesh (Alg. 3 + Alg. 2 on a table-driven decomposition).

    mask: global (n,) bool array (the feature mask; all-ones labels pure
    geometry).  mesh: 1-D device mesh with `decomp.nparts` devices (e.g.
    ``make_dpc_mesh(nparts)``).  Returns (labels, GraphDPCStats): labels is
    the global (n,) array carrying the largest vertex id of each component,
    -1 where unmasked — bit-identical to single-device
    `connected_components_graph`.
    """
    names = tuple(mesh.axis_names)
    if len(names) != 1:
        raise ValueError(f"graph CC needs a 1-D mesh, got axes {names}")
    name = names[0]
    if int(mesh.shape[name]) != decomp.nparts:
        raise ValueError(f"mesh has {mesh.shape[name]} devices but decomp "
                         f"has {decomp.nparts} partitions")
    dt = decomp.id_dtype
    mask = mask.ravel().astype(bool)
    if mask.shape[0] != decomp.n:
        raise ValueError(f"mask has {mask.shape[0]} entries for "
                         f"{decomp.n} vertices")

    lgid = jnp.asarray(decomp.local_gid, dtype=dt)
    valid = jnp.asarray(decomp.local_valid)
    # ghost input values ride the input scatter (deviation (g1) in
    # DESIGN.md): every partition reads its owned + one-ring mask here
    local_mask = jnp.where(valid, mask[jnp.clip(lgid, 0)], False)

    fn = partial(_cc_partition, dec=decomp, name=name,
                 gather_mask=gather_mask)
    spec = P(name, None)
    mapped = shard_map_norep(fn, mesh, (spec,) * 7,
                             (spec, GraphDPCStats(*([P()] * _N_STATS))))
    owned_stack, stats = mapped(
        local_mask, lgid, jnp.asarray(decomp.local_ghost),
        jnp.asarray(decomp.owned_lidx),
        jnp.asarray(decomp.edge_src), jnp.asarray(decomp.edge_dst),
        jnp.asarray(decomp.cut_lidx))

    # unpermute the (nparts, n_owned) owned labels back to global id order;
    # pad slots carry gid n and fall off the scatter (deviation (p))
    labels = jnp.zeros(decomp.n, dtype=dt).at[
        jnp.asarray(decomp.owned_gid.reshape(-1))].set(
        owned_stack.reshape(-1), mode="drop")
    return labels, stats


def distributed_connected_components_graph_batch(masks, decomp: GraphDecomp,
                                                 mesh: Mesh,
                                                 gather_mask: bool = True):
    """Batched `distributed_connected_components_graph`: masks is a (B, n)
    stack of feature masks over ONE decomposed mesh (the multi-tenant
    serving case: many masks / thresholds of the same geometry).  The
    per-partition program is vmapped inside one shard_map, so the single
    cut-table all_gather fires once for the whole batch (DESIGN.md §Serve).
    Returns ((B, n) labels, GraphDPCStats with a leading (B,) dim); per item
    bit-identical to the single-request call.
    """
    names = tuple(mesh.axis_names)
    if len(names) != 1:
        raise ValueError(f"graph CC needs a 1-D mesh, got axes {names}")
    name = names[0]
    if int(mesh.shape[name]) != decomp.nparts:
        raise ValueError(f"mesh has {mesh.shape[name]} devices but decomp "
                         f"has {decomp.nparts} partitions")
    dt = decomp.id_dtype
    masks = masks.reshape(masks.shape[0], -1).astype(bool)
    if masks.shape[1] != decomp.n:
        raise ValueError(f"masks have {masks.shape[1]} entries for "
                         f"{decomp.n} vertices")
    B = masks.shape[0]

    lgid = jnp.asarray(decomp.local_gid, dtype=dt)
    valid = jnp.asarray(decomp.local_valid)
    # (nparts, B, n_local): the ghost-input scatter (deviation (g1)) for
    # every request at once
    local_mask = jnp.where(valid[:, None, :],
                           masks[:, jnp.clip(lgid, 0)].transpose(1, 0, 2),
                           False)

    part_fn = partial(_cc_partition, dec=decomp, name=name,
                      gather_mask=gather_mask)

    def fn(local_mask, lgid, ghost, ol, es, er, cl):
        # local_mask: (1, B, n_local); the rest carry the singleton shard dim
        def one(m):
            return part_fn(m[None], lgid, ghost, ol, es, er, cl)
        owned, stats = jax.vmap(one)(local_mask[0])   # owned: (B, 1, n_owned)
        return owned.transpose(1, 0, 2), stats

    spec = P(name, None)
    bspec = P(name, None, None)
    mapped = shard_map_norep(
        fn, mesh, (bspec,) + (spec,) * 6,
        (bspec, GraphDPCStats(*([P(None)] * _N_STATS))))
    owned_stack, stats = mapped(
        local_mask, lgid, jnp.asarray(decomp.local_ghost),
        jnp.asarray(decomp.owned_lidx),
        jnp.asarray(decomp.edge_src), jnp.asarray(decomp.edge_dst),
        jnp.asarray(decomp.cut_lidx))

    labels = jnp.zeros((B, decomp.n), dtype=dt).at[
        :, jnp.asarray(decomp.owned_gid.reshape(-1))].set(
        owned_stack.transpose(1, 0, 2).reshape(B, -1), mode="drop")
    return labels, stats
