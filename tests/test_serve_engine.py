"""Batched engine == sequential facade, bit-for-bit (DESIGN.md §Serve).

The TopologyEngine may bucket, pad, batch, and cache however it likes; the
contract is that every result is bit-identical to the sequential
`repro.topology.submit` path on the same request — pinned here on mixed
heterogeneous workloads drawn from the ragged seed corpus, pure in-process
and distributed in an 8-fake-device subprocess (the dry-run rule: never set
the device-count flag globally).  The executable cache must actually hit on
repeated layouts: replaying a workload may not compile anything new.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np

from oracles import ragged_grid_case, ragged_graph_case

import jax.numpy as jnp

from repro.topology import TopologyRequest, submit_many
from repro.core.ids import compute_order
from repro.serve import TopologyEngine
from repro.serve.bucketing import (next_pow2, bucket_shape, batch_capacity,
                                   remap_flat_labels)

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_worker(script, args=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), os.path.dirname(__file__)])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", script] + list(args),
                          env=env, capture_output=True, text=True,
                          timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


def _assert_results_equal(got, want):
    assert got.query == want.query and got.tag == want.tag
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, b = getattr(got, f), getattr(want, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)


def _mixed_workload():
    """Heterogeneous pure requests: ragged grid extents (several sharing a
    bucket), both manifold directions, an MS, a sweep, and graph CCs."""
    reqs = []
    for seed in (0, 1, 2, 3):
        shape, _, conn, mask_p = ragged_grid_case(seed)
        rng = np.random.default_rng(100 + seed)
        reqs.append(TopologyRequest(
            "cc", mask=jnp.asarray(rng.random(shape) < mask_p),
            connectivity=conn, tag=f"cc{seed}"))
        if seed < 2:
            field = jnp.asarray(rng.standard_normal(shape))
            reqs.append(TopologyRequest(
                "manifold", order=compute_order(field), connectivity=conn,
                descending=bool(seed % 2), tag=f"mf{seed}"))
    shape, _, conn, _ = ragged_grid_case(0)
    rng = np.random.default_rng(7)
    field = jnp.asarray(rng.standard_normal(shape))
    reqs.append(TopologyRequest("ms", order=compute_order(field),
                                connectivity=conn, tag="ms"))
    reqs.append(TopologyRequest(
        "threshold_sweep", field=field,
        thresholds=jnp.asarray(np.quantile(np.asarray(field),
                                           [0.3, 0.6, 0.9])),
        connectivity=conn, tag="sweep"))
    n, s, r, _, _, mask = ragged_graph_case(0)
    reqs.append(TopologyRequest("cc", domain="graph",
                                mask=jnp.asarray(mask),
                                senders=jnp.asarray(s),
                                receivers=jnp.asarray(r), tag="gcc"))
    return reqs


def test_engine_matches_sequential_facade():
    reqs = _mixed_workload()
    eng = TopologyEngine(min_extent=8, max_batch=16)
    got = eng.submit_batch(reqs)
    want = submit_many(reqs)
    assert len(got) == len(want) == len(reqs)
    for g, w in zip(got, want):
        _assert_results_equal(g, w)
    s = eng.stats
    assert s.requests == len(reqs)
    # ms expands to 2 items, the 3-threshold sweep to 3
    assert s.items == len(reqs) + 1 + 2
    assert s.batches < s.items, "bucketing must actually batch"
    assert 0.0 <= s.pad_fraction < 1.0
    assert s.real_cells > 0 and s.padded_cells >= s.real_cells


def test_replay_hits_executable_cache():
    """Replaying the same layouts may not compile anything new: hit rate
    >= 0.5 cumulative, and misses stay frozen after the first pass."""
    reqs = _mixed_workload()
    eng = TopologyEngine(min_extent=8, max_batch=16)
    eng.submit_batch(reqs)
    misses_after_first = eng.stats.cache_misses
    assert misses_after_first == len(eng._exec)
    eng.submit_batch(reqs)
    assert eng.stats.cache_misses == misses_after_first
    assert eng.stats.hit_rate >= 0.5
    info = eng.cache_info()
    assert info["hits"] == eng.stats.cache_hits
    assert info["size"] == misses_after_first
    assert all(v >= 1 for v in info["runs_per_executable"].values())


def test_same_layout_requests_share_one_batch():
    rng = np.random.default_rng(0)
    reqs = [TopologyRequest("cc", mask=jnp.asarray(rng.random((9, 7)) < 0.6),
                            connectivity=4, tag=i) for i in range(3)]
    eng = TopologyEngine()
    got = eng.submit_batch(reqs)
    assert eng.stats.batches == 1 and eng.stats.cache_misses == 1
    for g, w in zip(got, submit_many(reqs)):
        _assert_results_equal(g, w)


def test_equal_shape_graphs_share_executable():
    """Edge lists are traced arguments: two different graphs of equal
    (n, m) bucket separately (correctness) but reuse one executable."""
    n1, s1, r1, _, _, m1 = ragged_graph_case(1)
    rng = np.random.default_rng(42)
    perm = rng.permutation(n1)
    s2, r2 = perm[np.asarray(s1)], perm[np.asarray(r1)]
    m2 = np.asarray(m1)[np.argsort(perm)]
    reqs = [TopologyRequest("cc", domain="graph", mask=jnp.asarray(m1),
                            senders=jnp.asarray(s1),
                            receivers=jnp.asarray(r1), tag="g1"),
            TopologyRequest("cc", domain="graph", mask=jnp.asarray(m2),
                            senders=jnp.asarray(s2),
                            receivers=jnp.asarray(r2), tag="g2")]
    eng = TopologyEngine()
    got = eng.submit_batch(reqs)
    assert eng.stats.batches == 2, "distinct graphs must not stack payloads"
    assert eng.stats.cache_misses == 1, "equal-shape graphs share the trace"
    for g, w in zip(got, submit_many(reqs)):
        _assert_results_equal(g, w)


def test_bucketing_helpers():
    assert [next_pow2(x) for x in (1, 2, 3, 8, 9)] == [1, 2, 4, 8, 16]
    assert bucket_shape((9, 7, 3), min_extent=8) == (16, 8, 8)
    assert batch_capacity(5, max_batch=64) == 8
    assert batch_capacity(100, max_batch=64) == 64
    # remap: unravel in padded shape, ravel in real shape; -1 preserved
    lab = np.array([[-1, 1], [8, 9]])       # padded shape (4, 8): id 8=(1,0)
    out = remap_flat_labels(np.pad(lab, ((0, 2), (0, 6)),
                                   constant_values=-1), (4, 8), (2, 2))
    np.testing.assert_array_equal(out, [[-1, 1], [2, 3]])


# --- distributed backend: engine == facade in an 8-device subprocess ---------


_DIST_WORKER = textwrap.dedent("""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import make_dpc_mesh
    from repro.core.distributed_graph import GraphDecomp
    from repro.core.ids import compute_order
    from repro.topology import TopologyRequest, submit_many
    from repro.serve import TopologyEngine

    mesh = make_dpc_mesh((2, 2))
    rng = np.random.default_rng(0)
    reqs = []
    for i, shape in enumerate([(9, 7), (9, 7), (11, 5)]):
        reqs.append(TopologyRequest(
            "cc", backend="distributed", mesh=mesh, connectivity=4,
            mask=jnp.asarray(rng.random(shape) < 0.6), tag=f"cc{i}"))
    field = rng.standard_normal((9, 7))
    reqs.append(TopologyRequest(
        "ms", backend="distributed", mesh=mesh, connectivity=4,
        order=compute_order(jnp.asarray(field)), tag="ms"))
    reqs.append(TopologyRequest(
        "threshold_sweep", backend="distributed", mesh=mesh, connectivity=4,
        field=jnp.asarray(field),
        thresholds=jnp.asarray(np.quantile(field, [0.4, 0.8])), tag="sw"))

    n, s, r, nparts, part, mask = 40, None, None, 4, None, None
    m = 90
    a, b = rng.integers(0, n, m), rng.integers(0, n, m)
    s, r = np.concatenate([a, b]), np.concatenate([b, a])
    part = rng.integers(0, nparts, n)
    dec = GraphDecomp(n, s, r, nparts, part=part)
    gmesh = make_dpc_mesh(nparts)
    mask = rng.random(n) < 0.7
    reqs.append(TopologyRequest(
        "cc", domain="graph", backend="distributed", mesh=gmesh, decomp=dec,
        mask=jnp.asarray(mask), senders=jnp.asarray(s),
        receivers=jnp.asarray(r), tag="gcc"))

    eng = TopologyEngine(min_extent=8, max_batch=8)
    got = eng.submit_batch(reqs)
    want = submit_many(reqs)
    for g, w in zip(got, want):
        assert g.tag == w.tag
        for f in ("labels", "ascending", "descending", "segmentation"):
            a_, b_ = getattr(g, f), getattr(w, f)
            assert (a_ is None) == (b_ is None), (g.tag, f)
            if a_ is not None:
                np.testing.assert_array_equal(np.asarray(a_),
                                              np.asarray(b_),
                                              err_msg=f"{g.tag}:{f}")
    # the paper's one-phase contract survives batching, per tenant
    # (sweep stats are per-threshold lists, ms stats nest per direction)
    for g in got:
        if not g.stats:
            continue
        v = g.stats.get("comm_phases",
                        g.stats.get("descending", {}).get("comm_phases"))
        ph = v if isinstance(v, list) else [v]
        assert all(x == 1 for x in ph), (g.tag, v)
    # the three same-bucket CC masks plus the two sweep masks batch into
    # fewer executions than items
    assert eng.stats.batches < eng.stats.items
    misses = eng.stats.cache_misses
    # replaying the workload compiles nothing new — all executions hit
    eng.submit_batch(reqs)
    assert eng.stats.cache_misses == misses
    assert eng.stats.cache_hits >= misses
    print("DIST_ENGINE_OK", eng.stats.batches, eng.stats.items)
""")


def test_engine_distributed_matches_facade():
    out = _run_worker(_DIST_WORKER)
    assert "DIST_ENGINE_OK" in out
