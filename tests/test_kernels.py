"""Per-kernel validation: Pallas (interpret=True on CPU) vs pure-jnp oracle,
sweeping shapes and dtypes as the deliverable requires."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.steepest_neighbor import steepest_neighbor
from repro.kernels.block_pathcompress import block_pathcompress
from repro.kernels.fused_local_phase import fused_local_phase
from repro.kernels.flash_attention import flash_attention
from repro.core.steepest import neighbor_offsets, grid_steepest

from oracles import GRID_SEED_CORPUS, ragged_grid_case

_ROOT = os.path.join(os.path.dirname(__file__), "..")


# --- steepest_neighbor -------------------------------------------------------


@pytest.mark.parametrize("shape", [(8, 8, 8), (16, 8, 8), (4, 16, 8),
                                   (32, 4, 4), (8, 5, 7)])
@pytest.mark.parametrize("conn", [6, 14])
def test_steepest_kernel_vs_ref(shape, conn):
    rng = np.random.default_rng(hash((shape, conn)) % 2**31)
    order = jnp.asarray(rng.permutation(int(np.prod(shape))).reshape(shape)
                        .astype(np.int32))
    got = steepest_neighbor(order, conn, block_x=4, interpret=True)
    want = ref.steepest_neighbor_ref(order, neighbor_offsets(3, conn))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_steepest_kernel_vs_core():
    """Kernel == the core library path used by DPC."""
    rng = np.random.default_rng(0)
    order = jnp.asarray(rng.permutation(8 * 8 * 8).reshape(8, 8, 8)
                        .astype(np.int32))
    got = steepest_neighbor(order, 6, block_x=2, interpret=True)
    want = grid_steepest(order, 6).reshape(order.shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("conn", [18, 26])
def test_steepest_kernel_full_neighborhoods(conn):
    """Digital-topology 18/26 neighborhoods (satellite of the fused-kernel
    PR): offset tables are symmetric and the kernel matches the oracle."""
    offs = neighbor_offsets(3, conn)
    assert len(offs) == conn
    assert all(tuple(-o for o in off) in offs for off in offs)
    rng = np.random.default_rng(conn)
    order = jnp.asarray(rng.permutation(8 * 5 * 7).reshape(8, 5, 7)
                        .astype(np.int32))
    got = steepest_neighbor(order, conn, block_x=4, interpret=True)
    want = ref.steepest_neighbor_ref(order, offs)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("block_x", [1, 2, 8])
def test_steepest_kernel_blocking_invariance(block_x):
    rng = np.random.default_rng(1)
    order = jnp.asarray(rng.permutation(8 * 6 * 6).reshape(8, 6, 6)
                        .astype(np.int32))
    got = steepest_neighbor(order, 6, block_x=block_x, interpret=True)
    want = ref.steepest_neighbor_ref(order, neighbor_offsets(3, 6))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --- fused_local_phase -------------------------------------------------------


def _fused_fixpoint_check(field, conn, mode, ptr):
    """The fused pointers must share their path_compress fixpoint with the
    plain unfused init — the contract that keeps final labels bit-identical."""
    from repro.core.pathcompress import path_compress
    from repro.core.steepest import grid_mask_argmax
    if mode == "manifold":
        d0 = grid_steepest(field, conn)
    else:
        d0 = grid_mask_argmax(field, conn)
    want, _ = path_compress(d0)
    got, _ = path_compress(ptr.ravel().astype(d0.dtype))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("conn", [6, 14, 18, 26])
@pytest.mark.parametrize("mode", ["manifold", "cc"])
def test_fused_kernel_vs_ref(conn, mode):
    """Kernel == bit-exact oracle (pointers AND round count) on a ragged
    prime extent with a tile size forcing a ragged last slab, plus the
    distributed self-mask override."""
    shape = (7, 3, 5)
    rng = np.random.default_rng(conn * 7 + (mode == "cc"))
    if mode == "manifold":
        field = jnp.asarray(rng.permutation(int(np.prod(shape)))
                            .reshape(shape).astype(np.int32))
    else:
        field = jnp.asarray(rng.random(shape) < 0.6)
    smask = jnp.asarray(rng.random(shape) < 0.2)
    got, rounds = fused_local_phase(field, conn, mode=mode, self_mask=smask,
                                    block_x=4, interpret=True)
    want, wrounds = ref.fused_local_phase_ref(field, conn, mode=mode,
                                              self_mask=smask, block_x=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(rounds) == int(wrounds) >= 1


@pytest.mark.parametrize("seed", GRID_SEED_CORPUS)
def test_fused_kernel_corpus(seed):
    """Ragged seed corpus (prime extents): kernel == oracle AND the fused
    pointers reach the same fixpoint as grid_steepest/grid_mask_argmax +
    path_compress (2-D corpus cases are covered by the dispatch fallback
    tests — the kernel itself is 3-D only)."""
    shape, _, conn, mask_p = ragged_grid_case(seed)
    if len(shape) != 3:
        pytest.skip("fused kernel is 3-D only")
    rng = np.random.default_rng(seed)
    order = jnp.asarray(rng.permutation(int(np.prod(shape)))
                        .reshape(shape).astype(np.int32))
    mask = jnp.asarray(rng.random(shape) < mask_p)
    for mode, field in (("manifold", order), ("cc", mask)):
        got, rounds = fused_local_phase(field, conn, mode=mode, block_x=4,
                                        interpret=True)
        want, wrounds = ref.fused_local_phase_ref(field, conn, mode=mode,
                                                  block_x=4)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        assert int(rounds) == int(wrounds)
        _fused_fixpoint_check(field, conn, mode, got)


@pytest.mark.parametrize("block_x", [1, 3, 8])
def test_fused_kernel_blocking_invariance(block_x):
    """Any tile size gives the same compress fixpoint (block_x=3 on x=13
    forces a ragged last slab; block_x=1 degenerates to pure init + the
    single-plane saturation)."""
    shape = (13, 2, 3)
    rng = np.random.default_rng(block_x)
    order = jnp.asarray(rng.permutation(int(np.prod(shape)))
                        .reshape(shape).astype(np.int32))
    got, _ = fused_local_phase(order, 6, mode="manifold", block_x=block_x,
                               interpret=True)
    want, _ = ref.fused_local_phase_ref(order, 6, mode="manifold",
                                        block_x=block_x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    _fused_fixpoint_check(order, 6, "manifold", got)


def test_fused_dispatch_fallback_and_validation():
    """ops.fused_local_phase: jnp fallback for 2-D fields and unsupported
    connectivities (kernel_rounds == 0), ValueError on a bad impl."""
    from repro.kernels import ops
    rng = np.random.default_rng(5)
    order2d = jnp.asarray(rng.permutation(30).reshape(5, 6).astype(np.int32))
    d, r = ops.fused_local_phase(order2d, connectivity=4, mode="manifold",
                                 impl="kernel")
    assert d.shape == (5, 6) and int(r) == 0
    order3d = jnp.asarray(rng.permutation(60).reshape(5, 4, 3)
                          .astype(np.int32))
    got = ops.fused_local_phase(order3d, 6, mode="manifold", impl="ref")[0]
    want = grid_steepest(order3d, 6).reshape(order3d.shape)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    with pytest.raises(ValueError, match="impl"):
        ops.fused_local_phase(order3d, 6, impl="nope")
    with pytest.raises(ValueError, match="mode"):
        ops.fused_local_phase(order3d, 6, mode="nope")


def test_fused_kernel_rejects_2d_and_bad_conn():
    rng = np.random.default_rng(6)
    order2d = jnp.asarray(rng.permutation(30).reshape(5, 6).astype(np.int32))
    with pytest.raises(ValueError, match="3-D"):
        fused_local_phase(order2d, 4)
    order3d = jnp.asarray(rng.permutation(60).reshape(5, 4, 3)
                          .astype(np.int32))
    with pytest.raises(ValueError, match="connectivit"):
        fused_local_phase(order3d, 5)


def test_steepest_kernel_rejects_2d_and_bad_conn():
    """Satellite: steepest_neighbor raises a clear ValueError instead of
    producing wrong halo geometry on inputs it cannot tile."""
    rng = np.random.default_rng(7)
    order2d = jnp.asarray(rng.permutation(30).reshape(5, 6).astype(np.int32))
    with pytest.raises(ValueError, match="3-D"):
        steepest_neighbor(order2d, 4, interpret=True)
    order3d = jnp.asarray(rng.permutation(60).reshape(5, 4, 3)
                          .astype(np.int32))
    with pytest.raises(ValueError, match="fallback"):
        steepest_neighbor(order3d, 5, interpret=True)


def test_fused_kernel_rejects_int64_without_x64():
    assert not jax.config.jax_enable_x64  # test-process invariant
    order = jnp.asarray(np.arange(24, dtype=np.int32).reshape(4, 3, 2))
    with pytest.raises(ValueError, match="x64"):
        fused_local_phase(order, 6, id_dtype=jnp.int64)


_FUSED_X64_WORKER = textwrap.dedent("""
    import os
    os.environ["JAX_ENABLE_X64"] = "1"
    import numpy as np
    import jax
    import jax.numpy as jnp
    from repro.kernels.fused_local_phase import fused_local_phase
    from repro.kernels.ref import fused_local_phase_ref

    assert jax.config.jax_enable_x64
    rng = np.random.default_rng(11)
    shape = (7, 3, 4)
    order = jnp.asarray(rng.permutation(int(np.prod(shape)))
                        .reshape(shape).astype(np.int32))
    got, r = fused_local_phase(order, 14, mode="manifold", block_x=4,
                               interpret=True, id_dtype=jnp.int64)
    assert got.dtype == jnp.int64
    want, wr = fused_local_phase_ref(order, 14, mode="manifold", block_x=4,
                                     id_dtype=jnp.int64)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(r) == int(wr)
    print("FUSED-X64-OK")
""")


def test_fused_kernel_int64_ids_under_x64():
    """Subprocess: the x64 flag is global, so the int64 pointer-id case must
    not leak into this (x64-off) test process."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    proc = subprocess.run([sys.executable, "-c", _FUSED_X64_WORKER], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "FUSED-X64-OK" in proc.stdout


def test_pure_entry_points_fused_parity():
    """descending/ascending manifold, ms_segmentation and CC grid labels are
    bit-identical between the default (jnp) and forced-kernel dispatch."""
    from repro.core.connected_components import connected_components_grid
    from repro.core.ms_segmentation import (ascending_manifold,
                                            descending_manifold,
                                            ms_segmentation)
    rng = np.random.default_rng(8)
    shape = (7, 4, 4)
    order = jnp.asarray(rng.permutation(int(np.prod(shape)))
                        .reshape(shape).astype(np.int32))
    mask = jnp.asarray(rng.random(shape) < 0.55)
    for fn in (descending_manifold, ascending_manifold):
        a, _ = fn(order, 6)
        b, _ = fn(order, 6, fused_impl="kernel")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    s1 = ms_segmentation(order, 6)
    s2 = ms_segmentation(order, 6, fused_impl="kernel")
    np.testing.assert_array_equal(np.asarray(s1.segmentation),
                                  np.asarray(s2.segmentation))
    c1 = connected_components_grid(mask, 6)
    c2 = connected_components_grid(mask, 6, fused_impl="kernel")
    np.testing.assert_array_equal(np.asarray(c1.labels),
                                  np.asarray(c2.labels))


# --- block_pathcompress ------------------------------------------------------


@pytest.mark.parametrize("n,block", [(64, 16), (256, 64), (1024, 1024),
                                     (128, 32),
                                     # ragged last tile (pad-and-mask,
                                     # deviation (p) in DESIGN.md)
                                     (100, 32), (97, 64), (130, 128)])
@pytest.mark.parametrize("rounds", [1, 3, 6])
def test_block_pathcompress_vs_ref(n, block, rounds):
    rng = np.random.default_rng(n + rounds)
    d = np.arange(n)
    for v in range(n - 1):
        if rng.random() < 0.85:
            d[v] = rng.integers(v + 1, n)
    d[rng.random(n) < 0.05] = -1
    d = jnp.asarray(d, dtype=jnp.int32)
    got = block_pathcompress(d, rounds=rounds, block=block, interpret=True)
    # per-block oracle
    want = jnp.concatenate([
        ref.block_pathcompress_ref(d[i:i + block], rounds, base=i)
        for i in range(0, n, block)])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_block_pathcompress_bucketed_recompile():
    """Satellite: request lengths snap to pow2 bucket capacities OUTSIDE the
    jit boundary, so one executable serves every length in a bucket (the
    serving engine replays ragged request streams; per-length recompiles
    were the cache-miss hot spot)."""
    from repro.kernels.block_pathcompress import _padded_call

    def chain(n, seed):
        rng = np.random.default_rng(seed)
        d = np.arange(n)
        for v in range(n - 1):
            if rng.random() < 0.8:
                d[v] = rng.integers(v + 1, n)
        return jnp.asarray(d, dtype=jnp.int32)

    _padded_call._clear_cache()
    for n in (100, 97, 80, 128):          # one bucket: cap 128
        d = chain(n, n)
        got = block_pathcompress(d, rounds=3, block=32, interpret=True)
        want = jnp.concatenate([
            ref.block_pathcompress_ref(d[i:i + 32], 3, base=i)
            for i in range(0, n, 32)])
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert _padded_call._cache_size() == 1
    block_pathcompress(chain(130, 0), rounds=3, block=32, interpret=True)
    assert _padded_call._cache_size() == 2  # new bucket: cap 256


def test_block_pathcompress_then_global_converges():
    """Block rounds + global rounds give the same fixpoint as global-only
    (the correctness argument for the TPU schedule)."""
    from repro.core import path_compress
    rng = np.random.default_rng(3)
    n = 512
    d = np.arange(n)
    for v in range(n - 1):
        if rng.random() < 0.9:
            d[v] = rng.integers(v + 1, n)
    d = jnp.asarray(d, dtype=jnp.int32)
    pre = block_pathcompress(d, rounds=4, block=64, interpret=True)
    out_hybrid, it_hybrid = path_compress(pre)
    out_global, it_global = path_compress(d)
    np.testing.assert_array_equal(np.asarray(out_hybrid),
                                  np.asarray(out_global))


# --- flash attention ---------------------------------------------------------


@pytest.mark.parametrize("b,h,hkv,sq,sk,dh", [
    (1, 4, 4, 128, 128, 64),
    (2, 4, 2, 128, 256, 64),    # GQA group 2
    (1, 8, 1, 128, 128, 128),   # MQA
    (2, 2, 2, 256, 128, 32),    # cross (kv shorter)
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_vs_mha(b, h, hkv, sq, sk, dh, dtype):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, h, sq, dh), dtype)
    k = jax.random.normal(k2, (b, hkv, sk, dh), dtype)
    v = jax.random.normal(k3, (b, hkv, sk, dh), dtype)
    got = flash_attention(q, k, v, causal=False, block_q=128, block_k=128,
                          interpret=True)
    want = ref.mha_ref(q, k, v, causal=False)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("sq,sk", [(128, 128), (128, 384), (256, 256)])
def test_flash_causal(sq, sk):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(k1, (1, 4, sq, 64))
    k = jax.random.normal(k2, (1, 2, sk, 64))
    v = jax.random.normal(k3, (1, 2, sk, 64))
    got = flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_ref_matches_mha_chunked():
    """The model-side chunked implementation == unfused reference."""
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(k1, (2, 8, 64, 32))
    k = jax.random.normal(k2, (2, 2, 192, 32))
    v = jax.random.normal(k3, (2, 2, 192, 32))
    got = ref.flash_attention_ref(q, k, v, causal=True, block_kv=64)
    want = ref.mha_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# --- segment_bag (EmbeddingBag) ----------------------------------------------


@pytest.mark.parametrize("v,d,b,l,vb,bb", [
    (64, 8, 16, 5, 16, 8),
    (256, 32, 32, 16, 64, 32),
    (100, 16, 24, 4, 100, 24),   # single tile
    (512, 4, 8, 3, 128, 4),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_segment_bag_vs_embedding_bag(v, d, b, l, vb, bb, dtype):
    from repro.kernels.segment_bag import segment_bag
    from repro.models.bst import embedding_bag
    key = jax.random.PRNGKey(v + b)
    table = jax.random.normal(key, (v, d), dtype)
    ids = jax.random.randint(jax.random.PRNGKey(1), (b, l), -1, v)
    got = segment_bag(table, ids, vocab_block=vb, batch_block=bb,
                      interpret=True)
    # oracle in f32 (the kernel accumulates f32; bf16 ref sums reorder)
    want = embedding_bag(table.astype(jnp.float32), ids).astype(dtype)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


def test_segment_bag_all_padding():
    from repro.kernels.segment_bag import segment_bag
    table = jnp.ones((32, 4))
    ids = jnp.full((8, 3), -1)
    got = segment_bag(table, ids, vocab_block=16, batch_block=8,
                      interpret=True)
    np.testing.assert_array_equal(np.asarray(got), 0.0)
