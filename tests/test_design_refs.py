"""DESIGN.md must stay the single source of truth for deviations: every
reference in the source tree ("deviation (x) in DESIGN.md", "DESIGN.md §Y")
must resolve to a heading, so the catalog can never dangle again (it was
referenced for two PRs before it existed)."""
import os
import re

_ROOT = os.path.join(os.path.dirname(__file__), "..")
_DESIGN = os.path.join(_ROOT, "DESIGN.md")

_DEVIATION_RE = re.compile(r"[Dd]eviation \(([a-z][0-9]?)\)")
_SECTION_RE = re.compile(r"DESIGN\.md §([A-Za-z0-9_-]+)")


def _py_files():
    for base in ("src", "tests", "benchmarks", "examples"):
        for dirpath, _, files in os.walk(os.path.join(_ROOT, base)):
            if "__pycache__" in dirpath:
                continue
            for f in files:
                # skip this checker itself (its docstrings name the ref
                # *patterns*, which are not real references)
                if f.endswith(".py") and f != "test_design_refs.py":
                    yield os.path.join(dirpath, f)


def _collect_refs():
    deviations, sections = set(), set()
    for path in _py_files():
        with open(path) as f:
            text = f.read()
        deviations.update(_DEVIATION_RE.findall(text))
        sections.update(_SECTION_RE.findall(text))
    return deviations, sections


def test_design_md_exists():
    assert os.path.exists(_DESIGN), "DESIGN.md is referenced but missing"


def test_all_deviation_refs_resolve():
    with open(_DESIGN) as f:
        design = f.read()
    deviations, sections = _collect_refs()
    assert deviations, "sanity: the tree references at least one deviation"
    missing = [x for x in sorted(deviations)
               if not re.search(rf"^## Deviation \({re.escape(x)}\)",
                                design, re.M)]
    assert not missing, (f"deviation(s) {missing} referenced in the tree "
                         "but not cataloged as '## Deviation (x)' headings "
                         "in DESIGN.md")
    missing = [s for s in sorted(sections)
               if not re.search(rf"^## §{re.escape(s)}\b", design, re.M)]
    assert not missing, (f"section(s) {missing} referenced as 'DESIGN.md §…' "
                         "but missing '## §…' headings in DESIGN.md")


def test_designmd_mentions_resolve_near_reference():
    """Any line mentioning DESIGN.md together with a deviation letter or §
    token must use a token that resolves (guards against typo'd letters on
    the same line as the DESIGN.md pointer)."""
    with open(_DESIGN) as f:
        design = f.read()
    bad = []
    for path in _py_files():
        with open(path) as f:
            for ln, line in enumerate(f, 1):
                if "DESIGN.md" not in line:
                    continue
                for x in _DEVIATION_RE.findall(line):
                    if not re.search(rf"^## Deviation \({re.escape(x)}\)",
                                     design, re.M):
                        bad.append((path, ln, f"deviation ({x})"))
                for s in _SECTION_RE.findall(line):
                    if not re.search(rf"^## §{re.escape(s)}\b", design, re.M):
                        bad.append((path, ln, f"§{s}"))
    assert not bad, f"dangling DESIGN.md references: {bad}"
