"""Pallas TPU kernel: fused EmbeddingBag (gather + segment-sum).

The recsys hot path (assignment §RecSys: "the embedding LOOKUP is the hot
path"; JAX has no native EmbeddingBag).  TPU adaptation: the table never
fits VMEM (10^6-10^9 rows), so instead of row-DMA chasing we tile the
VOCAB: grid = (vocab_tiles, batch_blocks); step (t, b) loads table tile t
(rows [t*Vb, (t+1)*Vb)) and the id block b into VMEM, accumulates the
partial bag sums for ids that fall inside the tile, and the sequential
vocab axis revisits the output block — one HBM pass over the table per
batch block, fully vectorised masking instead of scalar gathers.

This trades gather irregularity for a dense sweep: optimal when
batch * L >= vocab_tiles (training / bulk-serving shapes); ops.py keeps the
XLA gather path for the sparse-read regimes (serve_p99).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(ids_ref, table_ref, out_ref, *, vocab_block, n_tiles):
    t = pl.program_id(1)  # vocab tile — innermost (sequential on TPU), so
    #                       the revisited out block accumulates in VMEM
    ids = ids_ref[...]                 # (Bb, L) int32, -1 pads
    tile = table_ref[...]              # (Vb, D)
    lo = t * vocab_block
    local = ids - lo                   # (Bb, L)
    in_tile = (local >= 0) & (local < vocab_block)
    safe = jnp.clip(local, 0, vocab_block - 1)
    rows = jnp.take(tile, safe, axis=0).astype(jnp.float32)  # (Bb, L, D)
    rows = jnp.where(in_tile[..., None], rows, 0.0)
    partial = rows.sum(axis=1)                     # (Bb, D) f32 accumulate

    @pl.when(t == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += partial


@functools.partial(jax.jit, static_argnames=("vocab_block", "batch_block",
                                             "interpret"))
def segment_bag(table: jax.Array, ids: jax.Array, vocab_block: int = 2048,
                batch_block: int = 256, interpret: bool = True) -> jax.Array:
    """table: (V, D); ids: (B, L) int32 with -1 padding.  Returns (B, D)
    sum-bags in table.dtype (fp32 accumulation across vocab tiles).
    V % vocab_block == 0 or vocab_block clamped; same for B."""
    v, d = table.shape
    b, l = ids.shape
    if v % vocab_block:
        vocab_block = v
    if b % batch_block:
        batch_block = b
    n_tiles = v // vocab_block
    grid = (b // batch_block, n_tiles)
    kernel = functools.partial(_kernel, vocab_block=vocab_block,
                               n_tiles=n_tiles)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((batch_block, l), lambda i, t: (i, 0)),
            pl.BlockSpec((vocab_block, d), lambda i, t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((batch_block, d), lambda i, t: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), jnp.float32),
        interpret=interpret,
    )(ids, table).astype(table.dtype)
