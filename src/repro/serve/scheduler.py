"""Deadline-aware flush scheduling for the async serving plane
(DESIGN.md §Serve-v2).

PR 6's engine flushed on every `submit_batch`, so bucket occupancies were
whatever one caller happened to hand over and the pow2 batch capacities
rarely filled.  The `FlushScheduler` decouples *admission* from *execution*:
work items enqueue into per-bucket FIFO queues and a bucket flushes only

  * when it reaches its batch capacity (the pow2 capacity actually fills),
  * when the earliest deadline in it would otherwise be missed — `now >=
    deadline - estimate`, where the estimate is a measured per-layout
    execute time (EWMA of observed durations, `default_estimate` before the
    first observation), or
  * on explicit `drain()` (`pop_all`).

Time is injected, never read from the wall directly: `MonotonicClock` for
production, `VirtualClock` for tests and benchmarks — a deterministic
virtual time source the test advances by hand, which makes deadline-flush
sequences exactly reproducible (the testability deviation recorded in
DESIGN.md §Serve-v2).  Durations observed through a `VirtualClock` are 0
unless the engine charges measured wall time back to the clock
(`charge_execution_time`), so virtual-clock runs degrade gracefully to
"flush exactly at the deadline".

Serve-v3 (DESIGN.md §Serve-v3) grows the scheduler from a flush *detector*
into a *scheduler* proper: `due()` orders buckets by deadline slack
(most-overdue first), cold buckets estimate from a global cross-bucket
EWMA instead of flushing exactly at the deadline, and `shed()` / `purge()`
let the engine drop queued work whose deadline is already unmeetable
before wasting an execution on it.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any

# Conservative cold-start execute estimate (seconds).  With the historical
# default of 0.0, a never-measured bucket's flush_at equalled its earliest
# deadline, so the very first request in every bucket flushed exactly AT its
# deadline and missed it by the execution time (satellite bugfix, ISSUE 10).
# 50ms is on the order of one warm bucket execution on the smoke shapes —
# pessimistic enough to flush early, small enough not to starve batching.
COLD_START_ESTIMATE = 0.05

# Load-shedding policies (`FlushScheduler.shed` / engine `shed_policy`):
#   never    — keep everything; overload only rejects at admission
#   late     — shed entries whose deadline has already passed (now > d)
#   hopeless — shed entries that cannot finish in time (now + estimate > d)
SHED_POLICIES = ("never", "late", "hopeless")


def check_shed_policy(policy: str) -> str:
    if policy not in SHED_POLICIES:
        raise ValueError(f"shed_policy must be one of {SHED_POLICIES}, "
                         f"got {policy!r}")
    return policy


class VirtualClock:
    """Deterministic time source: advances only when told to."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += float(dt)
        return self._t


class MonotonicClock:
    """Wall time source (monotonic, so deadline arithmetic never jumps)."""

    def now(self) -> float:
        return time.monotonic()


@dataclasses.dataclass
class _Entry:
    """One queued work item with its admission metadata."""
    item: Any
    deadline: float | None
    enqueued_at: float


class FlushScheduler:
    """Per-bucket FIFO queues with capacity- and deadline-driven flushes.

    The scheduler only *decides* when a bucket should flush; popping and
    executing is the engine's job (`AsyncTopologyEngine._flush`), so the
    decision logic stays a pure function of (queues, clock, estimates) and
    unit-testable without compiling anything.
    """

    def __init__(self, capacity: int = 64, clock=None,
                 default_estimate: float | None = None, ewma: float = 0.5):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock if clock is not None else MonotonicClock()
        # None selects the conservative cold-start default; an explicit 0.0
        # restores the pre-v3 "flush exactly at the deadline" behaviour.
        self.default_estimate = float(COLD_START_ESTIMATE
                                      if default_estimate is None
                                      else default_estimate)
        self.ewma = float(ewma)
        self._queues: dict = {}       # bucket key -> list[_Entry]
        self._estimates: dict = {}    # bucket key -> EWMA execute seconds
        self._global: float | None = None   # cross-bucket EWMA (cold seed)

    # --- admission ------------------------------------------------------------

    def enqueue(self, key, item, deadline: float | None = None) -> int:
        """Queue one work item under its bucket key; returns the bucket's
        occupancy after the enqueue."""
        q = self._queues.setdefault(key, [])
        q.append(_Entry(item=item,
                        deadline=None if deadline is None else float(deadline),
                        enqueued_at=self.clock.now()))
        return len(q)

    def depth(self) -> int:
        """Total queued items across every bucket."""
        return sum(len(q) for q in self._queues.values())

    def depths(self) -> dict:
        return {k: len(q) for k, q in self._queues.items() if q}

    # --- flush decisions ------------------------------------------------------

    def full(self) -> list:
        """Bucket keys at (or beyond — a single request can expand past the
        capacity) their batch capacity."""
        return [k for k, q in self._queues.items() if len(q) >= self.capacity]

    def earliest_deadline(self, key) -> float | None:
        ds = [e.deadline for e in self._queues.get(key, ())
              if e.deadline is not None]
        return min(ds) if ds else None

    def flush_at(self, key) -> float | None:
        """Latest time the bucket can still flush without missing its
        earliest deadline: deadline minus the measured execute estimate."""
        d = self.earliest_deadline(key)
        return None if d is None else d - self.estimate(key)

    def slack(self, key) -> float | None:
        """Seconds until the bucket must flush (negative when overdue):
        `earliest_deadline - estimate - now`.  None without a deadline."""
        t = self.flush_at(key)
        return None if t is None else t - self.clock.now()

    def due(self) -> list:
        """Bucket keys whose earliest deadline would be missed by waiting
        any longer, ordered by deadline slack — most overdue first (stable,
        so equal-slack buckets keep insertion order and the schedule stays
        deterministic on a `VirtualClock`)."""
        now = self.clock.now()
        out = []
        for k, q in self._queues.items():
            if not q:
                continue
            t = self.flush_at(k)
            if t is not None and now >= t:
                out.append((t, k))
        out.sort(key=lambda pair: pair[0])
        return [k for _, k in out]

    def next_due_time(self) -> float | None:
        """Earliest `flush_at` across buckets (a poll-loop wakeup hint)."""
        times = [t for k in self._queues
                 if (t := self.flush_at(k)) is not None and self._queues[k]]
        return min(times) if times else None

    # --- draining -------------------------------------------------------------

    def pop(self, key) -> list:
        """Remove and return a bucket's queued entries (FIFO order)."""
        return self._queues.pop(key, [])

    def pop_all(self) -> dict:
        """Remove and return every non-empty queue (drain)."""
        out = {k: q for k, q in self._queues.items() if q}
        self._queues = {}
        return out

    # --- load shedding --------------------------------------------------------

    def shed(self, policy: str) -> list:
        """Remove and return `(key, entry)` pairs whose deadline is
        unmeetable under `policy` ("never" sheds nothing; "late" sheds
        already-missed deadlines; "hopeless" also sheds entries the current
        estimate says cannot finish in time).  Deciding what the dropped
        entries *mean* (failing handles, purging siblings) is the engine's
        job, keeping this a pure queue transformation."""
        check_shed_policy(policy)
        if policy == "never":
            return []
        now = self.clock.now()
        out = []
        for k in list(self._queues):
            cut = now if policy == "late" else now + self.estimate(k)
            keep, drop = [], []
            for e in self._queues[k]:
                (drop if e.deadline is not None and cut > e.deadline
                 else keep).append(e)
            if drop:
                out.extend((k, e) for e in drop)
                if keep:
                    self._queues[k] = keep
                else:
                    del self._queues[k]
        return out

    def purge(self, pred) -> list:
        """Remove and return every queued entry whose *item* satisfies
        `pred` (used to drop a shed request's sibling items from other
        buckets so no execution is wasted on them)."""
        out = []
        for k in list(self._queues):
            keep = [e for e in self._queues[k] if not pred(e.item)]
            if len(keep) != len(self._queues[k]):
                out.extend(e for e in self._queues[k] if pred(e.item))
                if keep:
                    self._queues[k] = keep
                else:
                    del self._queues[k]
        return out

    # --- execute-time estimates ----------------------------------------------

    def observe(self, key, seconds: float) -> None:
        """Fold one measured bucket-execution duration into the per-layout
        estimate (EWMA; the first observation replaces the default) and the
        global cross-bucket EWMA that seeds cold buckets."""
        s = float(seconds)
        prev = self._estimates.get(key)
        self._estimates[key] = (s if prev is None else
                                self.ewma * s + (1.0 - self.ewma) * prev)
        self._global = (s if self._global is None else
                        self.ewma * s + (1.0 - self.ewma) * self._global)

    def estimate(self, key) -> float:
        """Expected execute seconds for the bucket: its own EWMA, else the
        global cross-bucket EWMA (a cold bucket on a warm plane behaves
        like its peers), else the conservative cold-start default."""
        est = self._estimates.get(key)
        if est is not None:
            return est
        if self._global is not None:
            return self._global
        return self.default_estimate


__all__ = ["FlushScheduler", "VirtualClock", "MonotonicClock",
           "COLD_START_ESTIMATE", "SHED_POLICIES", "check_shed_policy"]
