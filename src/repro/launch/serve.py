"""Batched-serving launcher.

Two serving modes share this entry point:

  # LM prefill + decode loop with a KV cache (original mode)
  PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b --smoke \
      --batch 4 --prompt-len 32 --gen 16

  # Batched multi-tenant topology queries (DESIGN.md §Serve)
  PYTHONPATH=src python -m repro.launch.serve --topology --smoke \
      --requests 24 --repeat 2

  # Async deadline-aware plane, open-loop arrivals (DESIGN.md §Serve-v2)
  PYTHONPATH=src python -m repro.launch.serve --topology --async --smoke \
      --requests 24

  # Overload smoke: 4x-oversubscribed arrivals against tight admission
  # budgets; asserts typed rejections/sheds + parity (DESIGN.md §Serve-v3)
  PYTHONPATH=src python -m repro.launch.serve --topology --async --smoke \
      --requests 16 --overload
"""
from __future__ import annotations

import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models import lm
from repro.launch.mesh import make_smoke_mesh
from repro.runtime.meshctx import use_mesh


def serve_lm(args):
    mod = configs.get(args.arch)
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    key = jax.random.PRNGKey(args.seed)
    params = lm.init_params(key, cfg)
    max_len = args.prompt_len + args.gen

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    prefill = jax.jit(lambda p, t: lm.prefill(p, t, cfg, max_len=max_len))
    decode = jax.jit(lambda p, c, t: lm.decode_step(p, c, t, cfg),
                     donate_argnums=1)

    with use_mesh(make_smoke_mesh()):
        t0 = time.perf_counter()
        logits, cache = prefill(params, prompts)
        jax.block_until_ready(logits)
        t_prefill = time.perf_counter() - t0

        out = [jnp.argmax(logits, -1)[:, None]]
        t0 = time.perf_counter()
        for _ in range(args.gen - 1):
            logits, cache = decode(params, cache, out[-1])
            out.append(jnp.argmax(logits, -1)[:, None])
        jax.block_until_ready(logits)
        t_decode = time.perf_counter() - t0

    toks = np.asarray(jnp.concatenate(out, axis=1))
    tps = args.batch * (args.gen - 1) / max(t_decode, 1e-9)
    print(f"[serve] prefill {args.batch}x{args.prompt_len} in "
          f"{t_prefill * 1e3:.1f}ms; decode {args.gen - 1} steps at "
          f"{tps:.1f} tok/s (incl. compile)")
    print("[serve] sample continuation ids:", toks[0][:12])
    assert np.isfinite(np.asarray(logits)).all()
    return tps


def serve_topology(args):
    """Drive the batched topology engine over a synthetic mixed workload.

    `--repeat` replays the same request sequence (same layouts, so the same
    bucket occupancies), and the second pass is served entirely from the
    executable cache — the printed hit rate is the number to watch on
    repeated-layout traffic.
    """
    from repro.serve import TopologyEngine
    from repro.serve.workload import synthetic_requests

    mod = configs.get("serve_topology")
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    eng = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch,
                         cache_capacity=cfg.cache_capacity,
                         slot_cost_cells=cfg.slot_cost_cells or None)

    t_total = 0.0
    n_total = 0
    for rep in range(args.repeat):
        reqs = synthetic_requests(
            args.requests, cfg.shapes, mix=cfg.mix,
            connectivity=cfg.connectivity, sweep_k=cfg.sweep_k,
            seed=args.seed)
        t0 = time.perf_counter()
        results = eng.submit_batch(reqs)
        dt = time.perf_counter() - t0
        t_total += dt
        n_total += len(results)
        info = eng.stats.as_dict()
        print(f"[serve-topology] pass {rep}: {len(results)} requests in "
              f"{dt * 1e3:.1f}ms ({len(results) / max(dt, 1e-9):.1f} req/s); "
              f"cumulative hit_rate={info['hit_rate']:.2f} "
              f"pad_fraction={info['pad_fraction']:.2f}")
    print("[serve-topology] engine stats:",
          json.dumps(eng.stats.as_dict(), sort_keys=True))
    return n_total / max(t_total, 1e-9)


def serve_topology_async(args):
    """Drive the async deadline-aware plane over a replayable open-loop
    trace (DESIGN.md §Serve-v2).

    Arrivals and deadlines come from a `WorkloadTrace` (printed at the end,
    so any run is replayable from its log alone).  Time runs on a
    `VirtualClock` with measured execution wall time charged into it, so
    deadline hits/misses reflect real execute cost while the arrival
    schedule stays deterministic.
    """
    from repro.serve import AsyncTopologyEngine, VirtualClock
    from repro.serve.workload import synthetic_trace

    mod = configs.get("serve_topology")
    cfg = mod.smoke_config() if args.smoke else mod.full_config()
    trace = synthetic_trace(
        args.requests, cfg.shapes, mix=cfg.mix,
        connectivity=cfg.connectivity, sweep_k=cfg.sweep_k, seed=args.seed,
        rate=args.rate if args.rate is not None else cfg.rate,
        deadline_slack=(args.deadline_slack if args.deadline_slack is not None
                        else cfg.deadline_slack))
    eng = AsyncTopologyEngine(
        min_extent=cfg.min_extent, max_batch=cfg.max_batch,
        cache_capacity=cfg.cache_capacity,
        slot_cost_cells=cfg.slot_cost_cells or None,
        clock=VirtualClock(), charge_execution_time=True,
        max_queue_depth=cfg.max_queue_depth,
        max_inflight_cells=cfg.max_inflight_cells,
        shed_policy=cfg.shed_policy)

    t0 = time.perf_counter()
    handles = []
    for req, (t, dl) in zip(trace.requests(), trace.arrivals):
        if t > eng.clock.now():
            eng.advance(t - eng.clock.now())
        handles.append(eng.submit(req, deadline=dl))
    # run time out to the deadline horizon first (so deadline flushes get
    # their chance), then drain whatever never came under pressure
    horizon = max((dl for _, dl in trace.arrivals if dl is not None),
                  default=eng.clock.now())
    if horizon > eng.clock.now():
        eng.advance(horizon - eng.clock.now())
    eng.drain()
    wall = time.perf_counter() - t0
    assert all(h.done() for h in handles)

    s = eng.stats
    assert (s.flush_capacity + s.flush_deadline + s.flush_drain
            + s.flush_retry == s.batches)
    lat = np.asarray(eng.latencies)
    p50, p99 = (float(np.percentile(lat, 50)), float(np.percentile(lat, 99))
                ) if lat.size else (0.0, 0.0)
    print(f"[serve-async] {len(handles)} requests in {wall * 1e3:.0f}ms wall "
          f"({len(handles) / max(wall, 1e-9):.1f} req/s incl. compile); "
          f"flushes capacity={s.flush_capacity} deadline={s.flush_deadline} "
          f"drain={s.flush_drain} retry={s.flush_retry}; "
          f"deadline_hit_rate={s.deadline_hit_rate:.2f}; "
          f"latency p50={p50 * 1e3:.1f}ms p99={p99 * 1e3:.1f}ms (virtual); "
          f"evictions={s.cache_evictions} queue_peak={s.queue_depth_peak}; "
          f"rejected={s.rejected} shed={s.shed}")
    print("[serve-async] engine stats:",
          json.dumps(eng.stats.as_dict(), sort_keys=True))
    print("[serve-async] replay trace:",
          json.dumps(trace.as_dict(), sort_keys=True))
    return len(handles) / max(wall, 1e-9)


def serve_topology_overload(args):
    """Overload smoke (DESIGN.md §Serve-v3): measure the sustainable
    closed-loop rate, then replay an open-loop trace at
    `cfg.overload_factor` times it against tight admission budgets with
    `shed_policy="hopeless"`, and assert the overload contract — the
    remainder is rejected/shed with TYPED errors only (nothing escapes the
    plane), and every request that did complete is bit-identical to the
    sequential `submit_many` facade.
    """
    from repro.serve import (AsyncTopologyEngine, TopologyEngine,
                             VirtualClock, PlaneError,
                             SharedExecutableCache)
    from repro.serve.workload import overload_trace
    from repro.topology import submit_many

    mod = configs.get("serve_topology")
    cfg = mod.smoke_config() if args.smoke else mod.full_config()

    # sustainable rate: warm closed-loop pass on a sync engine attached to
    # the SAME SharedExecutableCache the overload engine will use — the
    # measurement pass pays the compiles once and the overload run starts
    # warm, so its estimates reflect execute cost, not compile cost
    from repro.serve.workload import synthetic_requests
    cache = SharedExecutableCache(capacity=cfg.cache_capacity)
    reqs = synthetic_requests(
        args.requests, cfg.shapes, mix=cfg.mix,
        connectivity=cfg.connectivity, sweep_k=cfg.sweep_k, seed=args.seed)
    sync = TopologyEngine(min_extent=cfg.min_extent, max_batch=cfg.max_batch,
                          slot_cost_cells=cfg.slot_cost_cells or None,
                          compile_cache=cache, name="measure")
    sync.submit_batch(reqs)                       # cold (compiles)
    t0 = time.perf_counter()
    sync.submit_batch(reqs)                       # warm
    sustainable = len(reqs) / max(time.perf_counter() - t0, 1e-9)

    trace = overload_trace(
        args.requests, cfg.shapes, mix=cfg.mix,
        connectivity=cfg.connectivity, sweep_k=cfg.sweep_k, seed=args.seed,
        sustainable_rps=sustainable, factor=cfg.overload_factor)
    eng = AsyncTopologyEngine(
        min_extent=cfg.min_extent, max_batch=cfg.max_batch,
        cache_capacity=cfg.cache_capacity,
        slot_cost_cells=cfg.slot_cost_cells or None,
        clock=VirtualClock(), charge_execution_time=True,
        max_queue_depth=cfg.overload_queue_depth,
        max_inflight_cells=cfg.max_inflight_cells,
        shed_policy="hopeless", default_estimate=1.0 / sustainable,
        compile_cache=cache, name="overload")

    handles = []
    for req, (t, dl) in zip(trace.requests(), trace.arrivals):
        if t > eng.clock.now():
            eng.advance(t - eng.clock.now())
        handles.append(eng.submit(req, deadline=dl))
    eng.drain()

    s = eng.stats
    # the overload contract
    assert all(h.done() for h in handles)
    for h in handles:
        exc = h.exception()
        assert exc is None or isinstance(exc, PlaneError), \
            f"non-typed error escaped the plane: {exc!r}"
    assert s.rejected + s.shed > 0, \
        f"{cfg.overload_factor}x overload produced no rejections/sheds"
    assert s.completed + s.failures + s.shed == s.requests
    assert (s.flush_capacity + s.flush_deadline + s.flush_drain
            + s.flush_retry == s.batches)
    completed = [(i, h) for i, h in enumerate(handles)
                 if h.exception() is None]
    if completed:
        want = submit_many([h.request for _, h in completed])
        for (_, h), w in zip(completed, want):
            for f in ("labels", "ascending", "descending", "segmentation"):
                a, b = getattr(h.result(), f), getattr(w, f)
                assert (a is None) == (b is None)
                if a is not None:
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
    n = len(handles)
    print(f"[serve-overload] {n} requests at "
          f"{cfg.overload_factor:.0f}x sustainable "
          f"({sustainable:.1f} req/s): completed={s.completed} "
          f"rejected={s.rejected} (depth-limited={s.queue_depth_limit}) "
          f"shed={s.shed} failures={s.failures}; "
          f"parity held on all {len(completed)} completed; "
          f"shared cache compiles={cache.compiles} "
          f"(async engine reused {eng.stats.cache_hits})")
    print("[serve-overload] engine stats:",
          json.dumps(eng.stats.as_dict(), sort_keys=True))
    print("[serve-overload] replay trace:",
          json.dumps(trace.as_dict(), sort_keys=True))
    return s.rejected + s.shed


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--topology", action="store_true",
                    help="serve batched CC/MS topology queries instead of LM")
    ap.add_argument("--requests", type=int, default=24,
                    help="topology mode: requests per pass")
    ap.add_argument("--repeat", type=int, default=2,
                    help="topology mode: workload passes (2nd hits the "
                         "executable cache)")
    ap.add_argument("--async", dest="async_plane", action="store_true",
                    help="topology mode: async deadline-aware plane with "
                         "open-loop arrivals (DESIGN.md §Serve-v2)")
    ap.add_argument("--rate", type=float, default=None,
                    help="async mode: Poisson arrival rate (req/s); "
                         "defaults to the config's")
    ap.add_argument("--deadline-slack", type=float, default=None,
                    help="async mode: mean deadline slack (s); defaults "
                         "to the config's")
    ap.add_argument("--overload", action="store_true",
                    help="async mode: 4x-oversubscribed overload smoke "
                         "asserting typed rejections/sheds + parity "
                         "(DESIGN.md §Serve-v3)")
    args = ap.parse_args(argv)
    if args.topology and args.async_plane and args.overload:
        return serve_topology_overload(args)
    if args.topology and args.async_plane:
        return serve_topology_async(args)
    if args.topology:
        return serve_topology(args)
    return serve_lm(args)


if __name__ == "__main__":
    main()
