"""Per-architecture smoke tests: instantiate the REDUCED config of each
assigned arch, run one forward/train step on CPU, assert output shapes and
no NaNs (deliverable f)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import lm, gnn, bst
from repro.optim import adamw

LM_ARCHS = ["stablelm_12b", "llama3_2_1b", "minitron_8b",
            "deepseek_moe_16b", "kimi_k2_1t"]
GNN_ARCHS = ["gat_cora", "schnet", "meshgraphnet", "dimenet"]


def _finite(tree):
    return all(bool(jnp.isfinite(x).all()) for x in jax.tree.leaves(tree)
               if jnp.issubdtype(x.dtype, jnp.floating))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_arch_smoke(arch):
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(key, cfg)
    toks = jax.random.randint(key, (2, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}

    # forward shapes
    h, aux = lm.forward(params, toks, cfg)
    assert h.shape == (2, 32, cfg.d_model)
    assert _finite({"h": h})

    # one train step reduces... is at least finite and updates params
    opt = adamw(1e-3)
    state = opt.init(params)
    (loss, m), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    new_params, state, om = opt.update(grads, state, params)
    assert _finite(new_params)
    changed = jax.tree.map(lambda a, b: bool((a != b).any()),
                           params, new_params)
    assert any(jax.tree.leaves(changed))

    # serve path: prefill + one decode step
    logits, cache = lm.prefill(params, toks, cfg, max_len=40)
    assert logits.shape == (2, cfg.vocab)
    nxt = jnp.argmax(logits, -1)[:, None]
    logits2, cache2 = lm.decode_step(params, cache, nxt, cfg)
    assert logits2.shape == (2, cfg.vocab)
    assert int(cache2["length"]) == 33
    assert _finite({"l": logits2})


@pytest.mark.parametrize("arch", GNN_ARCHS)
def test_gnn_arch_smoke(arch):
    from repro.data import graphs
    mod = configs.get(arch)
    cfg = mod.smoke_config()
    key = jax.random.PRNGKey(1)
    if cfg.arch in ("schnet", "dimenet"):
        g = graphs.molecule_batch(batch=4, n_nodes=8, n_edges=16, seed=0)
        expect_shape = (4,)
    elif cfg.arch == "gat":
        g = graphs.cora_like(0, n_nodes=96, n_edges=400,
                             d_feat=cfg.d_in, n_classes=cfg.n_classes)
        expect_shape = (96, cfg.n_classes)
    else:
        g = graphs.mesh_grid_graph(6, 7, d_node_in=cfg.d_node_in,
                                   d_edge_in=cfg.d_edge_in, d_out=cfg.d_out)
        expect_shape = (42, cfg.d_out)
    g = {k: (jnp.asarray(v) if isinstance(v, np.ndarray) else v)
         for k, v in g.items()}
    params = gnn.init_params(key, cfg)
    out = gnn.apply(params, g, cfg)
    assert out.shape == expect_shape
    assert _finite({"out": out})

    opt = adamw(1e-3)
    state = opt.init(params)
    (loss, aux), grads = jax.value_and_grad(gnn.loss_fn, has_aux=True)(
        params, g, cfg)
    assert np.isfinite(float(loss))
    new_params, _, _ = opt.update(grads, state, params)
    assert _finite(new_params)


def test_bst_arch_smoke():
    from repro.data import recsys
    cfg = configs.get("bst").smoke_config()
    key = jax.random.PRNGKey(2)
    params = bst.init_params(key, cfg)
    batch = {k: jnp.asarray(v)
             for k, v in recsys.bst_batch(cfg, 16, seed=0).items()}
    logits = bst.forward(params, batch, cfg)
    assert logits.shape == (16,)
    (loss, aux), grads = jax.value_and_grad(bst.loss_fn, has_aux=True)(
        params, batch, cfg)
    assert np.isfinite(float(loss))
    rb = {k: jnp.asarray(v) for k, v in
          recsys.retrieval_batch(cfg, 2, 512, seed=1).items()}
    vals, items = bst.retrieval_step(params, rb, cfg, top_k=8)
    assert vals.shape == (2, 8) and items.shape == (2, 8)
    assert _finite({"v": vals})


def test_dpc_grid_smoke():
    """The paper's own config: MS segmentation + CC on a small Perlin grid."""
    from repro.core import (ms_segmentation, connected_components_grid,
                            compute_order)
    from repro.data import perlin_noise
    cfg = configs.get("dpc_grid").smoke_config()
    field = perlin_noise((12, 10, 8), frequency=0.2, seed=1)
    order = compute_order(jnp.asarray(field))
    seg = ms_segmentation(order, cfg.connectivity)
    assert seg.segmentation.shape == (12, 10, 8)
    mask = jnp.asarray(field > np.quantile(field, cfg.threshold_quantile))
    res = connected_components_grid(mask, cfg.connectivity)
    labels = np.asarray(res.labels)
    assert (labels[np.asarray(mask)] >= 0).all()
    assert (labels[~np.asarray(mask)] == -1).all()


def test_dpc_graph_cell_smoke():
    """The unstructured workload's *launcher* path: build_dpc_graph_cell
    must construct (GraphDecomp + edge-list synthesis) and run a real step
    on the local smoke mesh for every shape — so a cell regression is
    caught per-PR, not in the nightly dryrun."""
    from repro.launch.cells import build_cell
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh()
    rng = np.random.default_rng(0)
    for shape_name in configs.get("dpc_graph").SMOKE_SHAPES:
        cell = build_cell("dpc_graph", shape_name, mesh, smoke=True)
        n = cell.arg_shapes[0].shape[0]
        mask = jnp.asarray(rng.random(n) < 0.5)
        labels, stats = cell.step_fn(mask)
        labels = np.asarray(labels)
        assert labels.shape == (n,)
        if cell.shape.get("geometry"):
            assert (labels >= 0).all()       # mask=ones: everything labeled
        else:
            assert (labels[~np.asarray(mask)] == -1).all()
            assert (labels[np.asarray(mask)] >= 0).all()
        assert int(stats.comm_phases) <= 1


def test_all_archs_registered():
    assert len(configs.ARCH_IDS) == 12  # 10 assigned + dpc_grid + dpc_graph
    for arch in configs.ARCH_IDS:
        mod = configs.get(arch)
        assert hasattr(mod, "FAMILY")
        assert mod.full_config() is not None
        assert mod.smoke_config() is not None
        # the §4 matrix: at least the four comparable shapes everywhere;
        # the DPC families add ragged prime-extent shapes on top
        assert len(mod.SHAPES) >= 4
        assert set(mod.SMOKE_SHAPES) == set(mod.SHAPES)


def test_param_counts_match_public_sizes():
    """The exact assigned configs must hit their published parameter counts
    (sanity that the configs are the real architectures)."""
    sizes = {
        "stablelm_12b": (12.1e9, 0.1),
        "llama3_2_1b": (1.5e9, 0.25),   # untied embeddings
        "minitron_8b": (9.9e9, 0.25),
        "deepseek_moe_16b": (17.2e9, 0.1),
        "kimi_k2_1t": (1.04e12, 0.1),
    }
    for arch, (expect, tol) in sizes.items():
        cfg = configs.get(arch).full_config()
        n = cfg.n_params()
        assert abs(n - expect) / expect < tol, f"{arch}: {n:.3e}"
    # kimi active params ~= 32B
    k = configs.get("kimi_k2_1t").full_config()
    assert abs(k.n_active_params() - 32e9) / 32e9 < 0.15
