"""Synthetic token pipeline for LM training: deterministic, seekable (exact
resume after restart — the fault-tolerance contract), with a planted
bigram structure so loss visibly decreases."""
from __future__ import annotations

import numpy as np


class TokenStream:
    """Infinite deterministic stream of (tokens, labels) batches.

    Seekable: `state()` returns the step counter; constructing with
    `start_step` resumes bit-identically (checkpoint/restart safe).
    """

    def __init__(self, vocab: int, batch: int, seq_len: int, seed: int = 0,
                 start_step: int = 0):
        self.vocab, self.batch, self.seq_len = vocab, batch, seq_len
        self.seed = seed
        self.step = start_step
        # planted bigram table: next-token = perm[token] with prob .8
        rng = np.random.default_rng(seed)
        self.perm = rng.permutation(vocab)

    def state(self) -> int:
        return self.step

    def __iter__(self):
        return self

    def __next__(self):
        rng = np.random.default_rng((self.seed, self.step))
        b, s, v = self.batch, self.seq_len, self.vocab
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, b)
        noise = rng.random((b, s)) < 0.2
        rand = rng.integers(0, v, (b, s))
        for t in range(s):
            nxt = self.perm[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t], rand[:, t], nxt)
        self.step += 1
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
