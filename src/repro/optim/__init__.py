from .adamw import adamw, sgd, apply_updates, global_norm, clip_by_global_norm
from .schedules import warmup_cosine, constant
from .compression import (topk_compress_decompress, int8_compress_decompress,
                          ErrorFeedbackState, compressed_gradients)
