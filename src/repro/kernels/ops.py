"""Jit'd public wrappers for the Pallas kernels.

On TPU the fused kernels run compiled (`interpret=False`); on CPU (this
container, and any unit-test environment) they execute in interpret mode and
are validated against the pure-jnp oracles in ref.py.  `impl="ref"` forces
the oracle — the dry-run lowers models with the ref implementations so the
HLO stays portable across backends.
"""
from __future__ import annotations

import jax

from . import ref
from .steepest_neighbor import steepest_neighbor as _steepest_kernel
from .block_pathcompress import block_pathcompress as _bpc_kernel
from .flash_attention import flash_attention as _flash_kernel
from .segment_bag import segment_bag as _bag_kernel


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def steepest_neighbor(order, connectivity: int = 6, impl: str = "auto",
                      block_x: int = 8):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        from repro.core.steepest import grid_steepest
        return grid_steepest(order, connectivity).reshape(order.shape)
    return _steepest_kernel(order, connectivity, block_x=block_x,
                            interpret=not _on_tpu())


def block_pathcompress(d, rounds: int = 4, block: int = 4096,
                       impl: str = "auto"):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.block_pathcompress_ref(d, rounds)  # block = whole array
    return _bpc_kernel(d, rounds=rounds, block=block,
                       interpret=not _on_tpu())


def flash_attention(q, k, v, causal: bool = False, impl: str = "auto",
                    block_q: int = 128, block_k: int = 128):
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        return ref.flash_attention_ref(q, k, v, causal=causal)
    return _flash_kernel(q, k, v, causal=causal, block_q=block_q,
                         block_k=block_k, interpret=not _on_tpu())


def embedding_bag(table, ids, impl: str = "auto", vocab_block: int = 2048,
                  batch_block: int = 256):
    """Fused EmbeddingBag.  The tiled kernel wins when batch*L sweeps a
    meaningful fraction of the table (train/bulk shapes); sparse-read
    serving keeps the XLA gather path."""
    if impl == "ref" or (impl == "auto" and not _on_tpu()):
        from repro.models.bst import embedding_bag as _ref_bag
        return _ref_bag(table, ids)
    return _bag_kernel(table, ids, vocab_block=vocab_block,
                       batch_block=batch_block, interpret=not _on_tpu())
