"""Async serving plane == sequential facade, bit-for-bit, under adversarial
scheduling (DESIGN.md §Serve-v2).

The AsyncTopologyEngine may queue, flush on capacity or deadline, split-
retry failed buckets, evict compiled executables, and dedup idempotency
replays however it likes; the contract is that every handle's result is
bit-identical to the sequential `repro.topology.submit` path on the same
request — pinned here across seed-deterministic random arrival orders,
deadlines, and mixed ragged shapes, plus fault-injection and LRU-eviction
suites.  All timing runs on the injected `VirtualClock`, so every flush
sequence in this file is exactly reproducible.
"""
import json
import math
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from oracles import ragged_grid_case, ragged_graph_case

import jax.numpy as jnp

from repro.topology import TopologyRequest, submit_many
from repro.core.ids import compute_order
from repro.serve import (TopologyEngine, AsyncTopologyEngine, FlushScheduler,
                         VirtualClock)
from repro.serve.bucketing import merge_adjacent_layouts, adjacent_layouts
from repro.serve.workload import (synthetic_requests, synthetic_trace,
                                  WorkloadTrace)

_ROOT = os.path.join(os.path.dirname(__file__), "..")


def _assert_results_equal(got, want):
    assert got.query == want.query and got.tag == want.tag
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, b = getattr(got, f), getattr(want, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)


def _flush_sum(stats):
    return (stats.flush_capacity + stats.flush_deadline + stats.flush_drain
            + stats.flush_retry)


def _cc(rng, shape=(9, 7), conn=4, tag=None):
    return TopologyRequest("cc", mask=jnp.asarray(rng.random(shape) < 0.6),
                           connectivity=conn, tag=tag)


def _mixed_requests(seed):
    """~6 heterogeneous pure requests over a FIXED shape pool (layouts stay
    shared across seeds so one engine's executables amortize), payloads
    varying with `seed`."""
    rng = np.random.default_rng(500 + seed)
    reqs = []
    for case in (0, 1):
        shape, _, conn, mask_p = ragged_grid_case(case)
        reqs.append(TopologyRequest(
            "cc", mask=jnp.asarray(rng.random(shape) < mask_p),
            connectivity=conn, tag=f"cc{case}"))
    shape, _, conn, _ = ragged_grid_case(0)
    field = jnp.asarray(rng.standard_normal(shape))
    reqs.append(TopologyRequest("manifold", order=compute_order(field),
                                connectivity=conn, descending=bool(seed % 2),
                                tag="mf"))
    reqs.append(TopologyRequest("ms", order=compute_order(field),
                                connectivity=conn, tag="ms"))
    reqs.append(TopologyRequest(
        "threshold_sweep", field=field,
        thresholds=jnp.asarray(np.quantile(np.asarray(field), [0.4, 0.8])),
        connectivity=conn, tag="sweep"))
    n, s, r, _, _, mask = ragged_graph_case(0)
    reqs.append(TopologyRequest("cc", domain="graph", mask=jnp.asarray(mask),
                                senders=jnp.asarray(s),
                                receivers=jnp.asarray(r), tag="gcc"))
    return reqs


# --- scheduler / clock units -------------------------------------------------


def test_virtual_clock():
    clk = VirtualClock()
    assert clk.now() == 0.0
    clk.advance(1.5)
    assert clk.now() == 1.5
    with pytest.raises(ValueError):
        clk.advance(-1.0)


def test_scheduler_capacity_and_drain():
    sch = FlushScheduler(capacity=2, clock=VirtualClock())
    assert sch.enqueue("a", "x1") == 1
    assert sch.full() == [] and sch.depth() == 1
    sch.enqueue("a", "x2")
    sch.enqueue("b", "y1")
    assert sch.full() == ["a"] and sch.depth() == 3
    got = [e.item for e in sch.pop("a")]
    assert got == ["x1", "x2"]          # FIFO
    rest = sch.pop_all()
    assert [e.item for e in rest["b"]] == ["y1"]
    assert sch.depth() == 0 and sch.pop("b") == []


def test_scheduler_deadline_uses_measured_estimate():
    clk = VirtualClock()
    sch = FlushScheduler(capacity=64, clock=clk, default_estimate=0.0,
                         ewma=0.5)
    sch.enqueue("k", "item", deadline=5.0)
    sch.enqueue("k", "later", deadline=9.0)
    assert sch.earliest_deadline("k") == 5.0
    assert sch.flush_at("k") == 5.0 and sch.due() == []
    # a measured execute estimate pulls the flush point earlier
    sch.observe("k", 2.0)
    assert sch.estimate("k") == 2.0 and sch.flush_at("k") == 3.0
    sch.observe("k", 4.0)               # EWMA: 0.5*4 + 0.5*2
    assert sch.estimate("k") == 3.0
    assert sch.next_due_time() == 2.0
    clk.advance(1.9)
    assert sch.due() == []
    clk.advance(0.1)
    assert sch.due() == ["k"]
    # entries without deadlines never force a flush
    sch2 = FlushScheduler(capacity=64, clock=clk)
    sch2.enqueue("k", "no-deadline")
    assert sch2.due() == [] and sch2.next_due_time() is None


# --- property parity: random arrivals, deadlines, mixed ragged shapes --------


def test_async_parity_random_arrival_orders():
    """Seed-deterministic random arrival orders, random deadlines, random
    clock advances: every handle bit-identical to submit_many; flush-reason
    counters sum to batches."""
    eng = AsyncTopologyEngine(min_extent=8, max_batch=4,
                              clock=VirtualClock())
    for seed in (0, 1, 2):
        rng = np.random.default_rng(9000 + seed)
        reqs = _mixed_requests(seed)
        want = submit_many(reqs)
        handles = {}
        for j in rng.permutation(len(reqs)):
            dl = (None if rng.random() < 0.4
                  else float(eng.clock.now() + rng.uniform(0.1, 3.0)))
            handles[int(j)] = eng.submit(reqs[j], deadline=dl)
            if rng.random() < 0.5:
                eng.advance(float(rng.uniform(0.0, 1.5)))
        eng.drain()
        for j, h in handles.items():
            assert h.done() and h.exception() is None
            _assert_results_equal(h.result(), want[j])
        assert _flush_sum(eng.stats) == eng.stats.batches
    s = eng.stats
    assert s.completed == s.requests and s.failures == 0
    assert s.latency_count == s.completed == len(eng.latencies)
    assert s.queue_depth_peak >= 2
    assert s.deadline_hits + s.deadline_misses <= s.completed


def test_capacity_flush_fills_the_pow2_batch():
    rng = np.random.default_rng(1)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=4,
                              clock=VirtualClock())
    hs = [eng.submit(_cc(rng, tag=i)) for i in range(4)]
    # the 4th submit filled the bucket: flushed without any drain/poll,
    # as ONE execution at full capacity
    assert all(h.done() for h in hs)
    assert eng.stats.flush_capacity == 1 and eng.stats.batches == 1
    assert eng.stats.padded_cells == 4 * 16 * 8   # (9,7) pads to (16,8)
    want = submit_many([h.request for h in hs])
    for h, w in zip(hs, want):
        _assert_results_equal(h.result(), w)


def test_deadline_flush_exactly_at_deadline():
    rng = np.random.default_rng(2)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=8,
                              clock=VirtualClock())
    h = eng.submit(_cc(rng, tag="solo"), deadline=5.0)
    assert not h.done() and eng.pending() == 1
    eng.advance(4.9)
    assert not h.done() and eng.stats.flush_deadline == 0
    # the cold-start estimate (0.05) pulls flush_at to 4.95; the next
    # advance crosses it and the request still completes by its deadline
    eng.advance(0.1)
    assert h.done() and eng.stats.flush_deadline == 1
    assert eng.stats.deadline_hits == 1 and eng.stats.deadline_misses == 0
    assert h.completed_at == 5.0 and eng.latencies == [5.0]
    assert _flush_sum(eng.stats) == eng.stats.batches


def test_result_forces_cooperative_drain():
    rng = np.random.default_rng(3)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=8,
                              clock=VirtualClock())
    h = eng.submit(_cc(rng, tag="lazy"))
    assert not h.done()
    res = h.result()                    # drains the engine
    assert h.done() and eng.stats.flush_drain >= 1
    _assert_results_equal(res, submit_many([h.request])[0])


# --- fault injection ----------------------------------------------------------


def _poisoned_engine(poison_tags, **kw):
    """Engine whose executor raises whenever a chosen request's item is in
    the executed group (the `_execute` seam exists for exactly this)."""
    eng = AsyncTopologyEngine(clock=VirtualClock(), **kw)
    tags = set(poison_tags)
    orig = AsyncTopologyEngine._execute

    def boom(fn, group, args):
        if any(eng._pending.get(g.req_idx) is not None
               and eng._pending[g.req_idx].request.tag in tags
               for g in group):
            raise RuntimeError("poisoned execution")
        return orig(eng, fn, group, args)

    eng._execute = boom
    return eng


def test_split_retry_isolates_the_poisoned_request():
    rng = np.random.default_rng(4)
    reqs = [_cc(rng, tag=i) for i in range(4)]
    want = submit_many(reqs)
    eng = _poisoned_engine({2}, min_extent=8, max_batch=8)
    hs = [eng.submit(r) for r in reqs]
    eng.drain()
    # only the offender's handle fails; the surviving cohort re-batched
    assert hs[2].exception() is not None
    assert "poisoned" in str(hs[2].exception())
    with pytest.raises(RuntimeError):
        hs[2].result()
    for i in (0, 1, 3):
        assert hs[i].exception() is None
        _assert_results_equal(hs[i].result(), want[i])
    s = eng.stats
    assert s.retries >= 1 and s.failures == 1 and s.completed == 3
    assert s.flush_retry >= 2
    assert _flush_sum(s) == s.batches, "counters stay consistent on failure"
    assert eng.pending() == 0 and not eng._outputs, "no orphaned outputs"

    # the engine stays servable after the failure
    h = eng.submit(_cc(rng, tag="after"))
    eng.drain()
    _assert_results_equal(h.result(), submit_many([h.request])[0])
    assert _flush_sum(eng.stats) == eng.stats.batches


def test_failure_of_one_item_fails_the_whole_request():
    """An MS request whose manifold items execute in a poisoned bucket
    surfaces ONE exception on its handle (not a half-result)."""
    rng = np.random.default_rng(5)
    shape = (5, 6)
    order = compute_order(jnp.asarray(rng.standard_normal(shape)))
    ms = TopologyRequest("ms", order=order, connectivity=4, tag="ms-poison")
    ok = _cc(rng, shape=shape, tag="ok")
    eng = _poisoned_engine({"ms-poison"}, min_extent=8, max_batch=8)
    h_ms, h_ok = eng.submit(ms), eng.submit(ok)
    eng.drain()
    assert h_ms.exception() is not None and h_ok.exception() is None
    _assert_results_equal(h_ok.result(), submit_many([ok])[0])
    assert eng.stats.failures == 1
    assert not eng._outputs, "sibling outputs of the failed request dropped"


def test_idempotency_replay_returns_cached_result_without_execution():
    rng = np.random.default_rng(6)
    req = _cc(rng, tag="idem")
    eng = AsyncTopologyEngine(min_extent=8, max_batch=8,
                              clock=VirtualClock())
    h1 = eng.submit(req, idempotency_key="tenant/1")
    h1b = eng.submit(req, idempotency_key="tenant/1")
    assert h1 is h1b, "in-flight replays share one handle"
    assert eng.stats.dedup_hits == 1 and eng.stats.requests == 1
    res = h1.result()
    batches = eng.stats.batches
    h2 = eng.submit(req, idempotency_key="tenant/1")
    assert h2.done() and h2.result() is res, "served from the result cache"
    assert eng.stats.batches == batches, "replay executed nothing"
    assert eng.stats.dedup_hits == 2
    # a different key executes normally
    h3 = eng.submit(req, idempotency_key="tenant/2")
    eng.drain()
    assert eng.stats.batches == batches + 1
    _assert_results_equal(h3.result(), res)


def test_failed_idempotent_request_is_not_cached():
    rng = np.random.default_rng(7)
    req = _cc(rng, tag="flaky")
    eng = _poisoned_engine({"flaky"}, min_extent=8, max_batch=8)
    h = eng.submit(req, idempotency_key="k")
    eng.drain()
    assert h.exception() is not None
    eng._execute = lambda fn, group, args: fn(*args)   # heal the executor
    h2 = eng.submit(req, idempotency_key="k")
    assert h2 is not h, "failures are not cached; the replay re-executes"
    eng.drain()
    _assert_results_equal(h2.result(), submit_many([req])[0])


# --- bounded LRU executable cache --------------------------------------------


def test_lru_bound_holds_and_evicted_layout_recompiles_bit_identically():
    rng = np.random.default_rng(8)
    eng = TopologyEngine(min_extent=8, max_batch=4, cache_capacity=2)
    shapes = [(5, 5), (9, 9), (17, 17)]
    reqs = [_cc(rng, shape=s, tag=i) for i, s in enumerate(shapes)]
    want = submit_many(reqs)
    for r, w in zip(reqs, want):
        _assert_results_equal(eng.submit(r), w)
        assert len(eng._exec) <= 2, "cache never exceeds cache_capacity"
    assert eng.stats.cache_evictions == 1
    # the first layout was evicted: re-serving it recompiles (a miss, a
    # second eviction) but stays bit-identical
    misses = eng.stats.cache_misses
    _assert_results_equal(eng.submit(reqs[0]), want[0])
    assert eng.stats.cache_misses == misses + 1
    assert eng.stats.cache_evictions == 2 and len(eng._exec) <= 2
    info = eng.cache_info()
    assert info["evictions"] == 2 and info["capacity"] == 2
    assert info["size"] == len(eng._exec) <= 2


def test_lru_recency_keeps_the_hot_layout():
    rng = np.random.default_rng(9)
    eng = TopologyEngine(min_extent=8, max_batch=4, cache_capacity=2)
    a, b, c = [_cc(rng, shape=s) for s in [(5, 5), (9, 9), (17, 17)]]
    eng.submit(a)                       # cache: [A]
    eng.submit(b)                       # cache: [A, B]
    eng.submit(a)                       # touch A -> cache: [B, A]
    eng.submit(c)                       # evicts B (least recent)
    misses = eng.stats.cache_misses
    eng.submit(a)                       # A survived: hit, no compile
    assert eng.stats.cache_misses == misses


def test_default_capacity_keeps_replay_compiling_nothing():
    """Regression for the PR 6 contract: at the DEFAULT cache capacity a
    replayed workload never evicts, so it compiles nothing new."""
    reqs = _mixed_requests(0)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=16,
                              clock=VirtualClock())
    for r in reqs:
        eng.submit(r)
    eng.drain()
    misses = eng.stats.cache_misses
    assert eng.stats.cache_evictions == 0
    for r in reqs:
        eng.submit(r)
    eng.drain()
    assert eng.stats.cache_misses == misses, "replay compiled something"
    assert eng.stats.cache_evictions == 0


# --- cost-model layout merging ------------------------------------------------


def test_adjacent_layouts_relation():
    assert adjacent_layouts((8, 8), (16, 8))
    assert adjacent_layouts((8, 8), (8, 16))
    assert not adjacent_layouts((8, 8), (16, 16)), "4x cells is not one step"
    assert not adjacent_layouts((16, 8), (8, 16)), "no domination"
    assert not adjacent_layouts((8, 8), (8, 8)), "identity is not a merge"
    assert not adjacent_layouts((8,), (8, 8)), "rank must match"


def test_merge_plan_cost_threshold():
    counts = {(8, 8): 3, (16, 8): 2}
    # extra pad = (128 - 64) * 3 = 192 cells < 1000 -> merge
    plan = merge_adjacent_layouts(counts, slot_cost_cells=1000)
    assert plan == {(8, 8): (16, 8), (16, 8): (16, 8)}
    # 192 >= 100 -> keep both executables
    plan = merge_adjacent_layouts(counts, slot_cost_cells=100)
    assert plan == {(8, 8): (8, 8), (16, 8): (16, 8)}
    # disabled
    assert merge_adjacent_layouts(counts, 0) == \
        {(8, 8): (8, 8), (16, 8): (16, 8)}


def test_merge_plan_chains_respect_pad_bound():
    # regression (ISSUE 10): pre-v3 this 3-layout chain path-compressed to
    # (8,) -> (16,) -> (32,), executing (8,) at 4x its cells and violating
    # the documented <=2x pad bound (DESIGN.md §Serve-v2).  The bound now
    # holds for every ORIGINAL layout along the chain: (16,) cannot absorb
    # the group carrying (8,), so the chain stops at (16,).
    plan = merge_adjacent_layouts({(8,): 1, (16,): 1, (32,): 1},
                                  slot_cost_cells=10**6)
    assert plan == {(8,): (16,), (16,): (16,), (32,): (32,)}
    assert all(math.prod(tgt) <= 2 * math.prod(orig)
               for orig, tgt in plan.items())
    # a longer lattice run: the bound holds pairwise along the whole chain
    plan = merge_adjacent_layouts({(8,): 5, (16,): 5, (32,): 5, (64,): 5},
                                  slot_cost_cells=10**6)
    assert all(math.prod(tgt) <= 2 * math.prod(orig)
               for orig, tgt in plan.items())


def test_engine_merges_adjacent_buckets_bit_identically():
    rng = np.random.default_rng(10)
    reqs = [_cc(rng, shape=s, tag=i)
            for i, s in enumerate([(5, 5), (9, 5), (5, 5)])]
    want = submit_many(reqs)
    merged = TopologyEngine(min_extent=8, max_batch=8,
                            slot_cost_cells=10**6)
    got = merged.submit_batch(reqs)
    # layouts (8,8) and (16,8) folded into ONE executable and ONE batch
    assert merged.stats.batches == 1 and merged.stats.cache_misses == 1
    for g, w in zip(got, want):
        _assert_results_equal(g, w)
    # without the merge policy the same workload needs two of each
    plain = TopologyEngine(min_extent=8, max_batch=8)
    plain.submit_batch(reqs)
    assert plain.stats.batches == 2 and plain.stats.cache_misses == 2
    # merging wastes cells by design; the cost model bounded it
    assert merged.stats.padded_cells >= plain.stats.padded_cells


def test_async_drain_applies_merge_policy():
    rng = np.random.default_rng(11)
    reqs = [_cc(rng, shape=s, tag=i)
            for i, s in enumerate([(5, 5), (9, 5)])]
    want = submit_many(reqs)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=8,
                              slot_cost_cells=10**6, clock=VirtualClock())
    hs = [eng.submit(r) for r in reqs]
    eng.drain()
    assert eng.stats.batches == 1
    for h, w in zip(hs, want):
        _assert_results_equal(h.result(), w)


# --- replayable workload traces ----------------------------------------------


def test_workload_seed_is_required():
    with pytest.raises(TypeError):
        synthetic_requests(3, ((5, 5),))                  # no seed
    with pytest.raises(TypeError):
        synthetic_requests(3, ((5, 5),), 0)               # not positional


def test_workload_trace_replays_bit_identically():
    trace = synthetic_trace(5, ((7, 5), (6, 6)), connectivity=4, sweep_k=2,
                            seed=3, rate=2.0, deadline_slack=1.0)
    r1, r2 = trace.requests(), trace.requests()
    assert len(r1) == len(r2) == 5
    for a, b in zip(r1, r2):
        assert a.query == b.query and a.tag == b.tag
        for f in ("mask", "order", "field", "thresholds"):
            va, vb = getattr(a, f), getattr(b, f)
            assert (va is None) == (vb is None)
            if va is not None:
                np.testing.assert_array_equal(np.asarray(va), np.asarray(vb))
    assert len(trace.arrivals) == 5
    ts = [t for t, _ in trace.arrivals]
    assert ts == sorted(ts) and all(d > t for t, d in trace.arrivals)
    # JSON round-trip preserves the trace exactly (the CI-repro contract)
    rt = WorkloadTrace.from_dict(json.loads(json.dumps(trace.as_dict())))
    assert rt == trace
    # arrival timing is a separate stream: closed trace has same payloads
    closed = synthetic_trace(5, ((7, 5), (6, 6)), connectivity=4, sweep_k=2,
                             seed=3)
    assert closed.arrivals == ()
    for a, b in zip(closed.requests(), r1):
        assert a.query == b.query


# --- distributed backend: async plane in an 8-device subprocess --------------


def _run_worker(script):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(_ROOT, "src"), os.path.dirname(__file__)])
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    proc = subprocess.run([sys.executable, "-c", script], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    return proc.stdout


_ASYNC_DIST_WORKER = textwrap.dedent("""
    import numpy as np
    import jax.numpy as jnp
    from repro.core import make_dpc_mesh
    from repro.topology import TopologyRequest, submit_many
    from repro.serve import AsyncTopologyEngine, VirtualClock

    mesh = make_dpc_mesh((2, 2))
    rng = np.random.default_rng(0)
    reqs = [TopologyRequest("cc", backend="distributed", mesh=mesh,
                            connectivity=4,
                            mask=jnp.asarray(rng.random((9, 7)) < 0.6),
                            tag=i) for i in range(3)]
    want = submit_many(reqs)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=2,
                              clock=VirtualClock())
    h0 = eng.submit(reqs[0], deadline=1.0)
    assert not h0.done()
    h1 = eng.submit(reqs[1])            # fills capacity 2 -> flush
    assert h0.done() and h1.done()
    h2 = eng.submit(reqs[2], deadline=0.5)
    assert not h2.done()
    eng.advance(0.5)                    # deadline flush
    assert h2.done()
    for h, w in zip((h0, h1, h2), want):
        np.testing.assert_array_equal(np.asarray(h.result().labels),
                                      np.asarray(w.labels), err_msg=str(h.request.tag))
        # the paper's one-phase budget survives the async plane, per tenant
        assert h.result().stats["comm_phases"] == 1
    s = eng.stats
    assert s.flush_capacity == 1 and s.flush_deadline == 1
    assert (s.flush_capacity + s.flush_deadline + s.flush_drain
            + s.flush_retry) == s.batches
    assert s.deadline_hits == 2

    # serve-v3 bugfix sweep: the engine's executables run under jit, where
    # check_converged is a no-op — the host-side re-check must surface a
    # too-small table_max_iter as a RuntimeError on the handle instead of
    # silently returning mid-chain labels
    bad = TopologyRequest("cc", backend="distributed", mesh=mesh,
                          connectivity=4, table_max_iter=1,
                          mask=jnp.asarray(rng.random((9, 7)) < 0.6),
                          tag="bad")
    hb = eng.submit(bad)
    eng.drain()
    assert hb.done() and isinstance(hb.exception(), RuntimeError)
    assert "max_iter" in str(hb.exception())
    assert s.failures == 1
    assert (s.flush_capacity + s.flush_deadline + s.flush_drain
            + s.flush_retry) == s.batches
    print("ASYNC_DIST_OK", s.batches)
""")


def test_async_distributed_matches_facade():
    out = _run_worker(_ASYNC_DIST_WORKER)
    assert "ASYNC_DIST_OK" in out
