"""VTK-connectivity stand-in baseline (paper §5, Tab. 1-3 comparisons).

The VTK filter runs a *connected wave propagation* locally and merges region
graphs globally.  The closest TPU-expressible analogue is plain label
propagation: every masked vertex repeatedly takes the max label over its
masked neighborhood.  Convergence needs O(component diameter) rounds versus
O(log diameter) for DPC pointer doubling — the algorithmic gap the paper's
benchmarks exercise.

`explicit=True` models VTK's structured->unstructured extraction: the masked
subgraph is materialised as an edge list first (the paper's memory-blowup
argument: extraction costs O(#masked * degree) index memory, while implicit
DPC only ever holds one extra label array).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from .steepest import neighbor_offsets, shift_fill


class BaselineCC(NamedTuple):
    labels: jax.Array
    n_rounds: jax.Array


@partial(jax.jit, static_argnames=("connectivity", "max_rounds"))
def label_propagation_grid(mask: jax.Array, connectivity: int = 6,
                           max_rounds: int = 100_000) -> BaselineCC:
    n = mask.size
    dtype = jnp.int32 if n < 2**31 else jnp.int64
    ids = jnp.arange(n, dtype=dtype).reshape(mask.shape)
    labels = jnp.where(mask, ids, dtype(-1))
    offsets = neighbor_offsets(mask.ndim, connectivity)

    def sweep(lab):
        best = lab
        for off in offsets:
            best = jnp.maximum(best, shift_fill(lab, off, dtype(-1)))
        return jnp.where(mask, best, dtype(-1))

    def cond(state):
        _, changed, r = state
        return changed & (r < max_rounds)

    def body(state):
        lab, _, r = state
        nxt = sweep(lab)
        return nxt, jnp.any(nxt != lab), r + jnp.int32(1)

    labels, _, rounds = lax.while_loop(
        cond, body, (labels, jnp.asarray(True), jnp.int32(0))
    )
    return BaselineCC(labels, rounds)


def extract_masked_edges(mask: jax.Array, connectivity: int = 6):
    """Explicit extraction (the VTK model): materialise the masked subgraph's
    directed edge list.  Returned padded to the full grid-edge count — the
    memory cost the paper's Tab. 3 attributes to VTK connectivity."""
    n = mask.size
    mask_flat = mask.ravel().astype(bool)
    ids = jnp.arange(n, dtype=jnp.int32).reshape(mask.shape)
    send, recv, valid = [], [], []
    for off in neighbor_offsets(mask.ndim, connectivity):
        nb = shift_fill(ids, off, -1)
        ok = mask_flat & (nb.ravel() >= 0) & \
            shift_fill(mask, off, False).ravel()
        send.append(jnp.where(ok, ids.ravel(), -1))
        recv.append(jnp.where(ok, nb.ravel(), -1))
        valid.append(ok)
    return (jnp.concatenate(send), jnp.concatenate(recv),
            jnp.concatenate(valid))
