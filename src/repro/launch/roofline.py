import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (same rule as dryrun.py).

"""Roofline analysis from the compiled dry-run artifacts (§Roofline).

Terms per (arch x shape) on the single-pod mesh, TPU v5e constants:
  compute    = HLO_FLOPs_per_device   / 197e12  FLOP/s
  memory     = HLO_bytes_per_device   / 819e9   B/s
  collective = collective_bytes/device / 50e9   B/s (result-shape sum over
               all-gather/all-reduce/reduce-scatter/all-to-all/permute)

`lax.scan` bodies are cost-analyzed ONCE by XLA, so layer-scanned models
(LM archs, MeshGraphNet) are corrected by lowering L=1 and L=2 variants:
  metric(L) = m1 + (L-1) * (m2 - m1).
DPC cells iterate data-dependent `while` loops; their terms are PER
DOUBLING ROUND (noted in the table).

  PYTHONPATH=src python -m repro.launch.roofline            # full table
  PYTHONPATH=src python -m repro.launch.roofline --arch kimi-k2-1t-a32b
"""
import argparse
import dataclasses
import json

import jax

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_SCANNED = {"lm": "n_layers", "gnn-mgn": "n_layers"}


def _load(out_dir, arch, shape):
    p = os.path.join(out_dir, f"{arch.replace('-', '_')}__{shape}.json")
    if not os.path.exists(p):
        return None
    with open(p) as f:
        return json.load(f)


def _metrics(rec):
    return {
        "flops": rec["cost"].get("flops", 0.0),
        "bytes": rec["cost"].get("bytes accessed", 0.0),
        "coll": float(rec["collectives"]["total"]),
        "transc": rec["cost"].get("transcendentals", 0.0),
    }


def _lower_variant(arch, shape, mesh, n_layers):
    from repro.launch.dryrun import collective_bytes
    from repro.launch.cells import build_cell
    from repro.runtime.meshctx import use_mesh

    def tr(cfg):
        # unroll == n_layers inlines the scan body n_layers times, so the
        # cost analysis really scales with the layer count
        return dataclasses.replace(cfg, n_layers=n_layers,
                                   scan_unroll=n_layers)

    cell = build_cell(arch, shape, mesh, cfg_transform=tr)
    with use_mesh(mesh):
        fn = jax.jit(cell.step_fn, in_shardings=cell.arg_shardings,
                     donate_argnums=cell.donate_argnums)
        compiled = fn.lower(*cell.arg_shapes).compile()
    cost = compiled.cost_analysis() or {}
    coll = collective_bytes(compiled.as_text())
    return {"flops": cost.get("flops", 0.0),
            "bytes": cost.get("bytes accessed", 0.0),
            "coll": float(coll["total"]),
            "transc": cost.get("transcendentals", 0.0)}


def scan_corrected_metrics(arch, shape, mesh, rec, cache_dir):
    """metric(L) = m1 + (L-1)(m2 - m1) via L=1/L=2 lowers (cached)."""
    from repro import configs
    cfg = configs.get(arch).full_config()
    L = cfg.n_layers
    cpath = os.path.join(cache_dir,
                         f"{arch.replace('-', '_')}__{shape}__scancorr.json")
    if os.path.exists(cpath):
        with open(cpath) as f:
            c = json.load(f)
    else:
        m1 = _lower_variant(arch, shape, mesh, 1)
        m2 = _lower_variant(arch, shape, mesh, 2)
        c = {"m1": m1, "m2": m2}
        os.makedirs(cache_dir, exist_ok=True)
        with open(cpath, "w") as f:
            json.dump(c, f)
    out = {}
    for k in ("flops", "bytes", "coll", "transc"):
        body = c["m2"][k] - c["m1"][k]
        out[k] = c["m1"][k] + max(body, 0.0) * (L - 1)
    return out


def model_flops(arch, shape_name, shape, n_devices):
    """6*N*D train / 2*N*D serving (per the assignment's definition),
    N = active params; LM-family only (— for others)."""
    from repro import configs
    mod = configs.get(arch)
    if mod.FAMILY != "lm":
        return None
    cfg = mod.full_config()
    n_act = cfg.n_active_params()
    if shape["kind"] == "train":
        d = shape["batch"] * shape["seq"]
        total = 6 * n_act * d
    elif shape["kind"] == "prefill":
        total = 2 * n_act * shape["batch"] * shape["seq"]
    else:  # decode: one token per sequence
        total = 2 * n_act * shape["batch"]
    return total / n_devices


def analyze_cell(arch, shape_name, rec, mesh, cache_dir):
    from repro import configs
    mod = configs.get(arch)
    shape = mod.SHAPES[shape_name]
    n_dev = 1
    for v in rec["mesh_shape"].values():
        n_dev *= v
    m = _metrics(rec)
    corrected = False
    if mod.FAMILY == "lm" or (mod.FAMILY == "gnn"
                              and getattr(mod.full_config(), "arch", "")
                              == "meshgraphnet"):
        try:
            m = scan_corrected_metrics(arch, shape_name, mesh, rec, cache_dir)
            corrected = True
        except Exception as e:  # noqa: BLE001
            print(f"[roofline] scan-correction failed for "
                  f"{arch}:{shape_name}: {e}; using raw HLO metrics")
    t_comp = m["flops"] / PEAK_FLOPS
    t_mem = m["bytes"] / HBM_BW
    t_coll = m["coll"] / ICI_BW
    terms = {"compute_s": t_comp, "memory_s": t_mem, "collective_s": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(arch, shape_name, shape, n_dev)
    ratio = (mf / m["flops"]) if (mf and m["flops"]) else None
    bound = max(terms.values())
    roofline_frac = (mf / PEAK_FLOPS) / bound if (mf and bound) else None
    return {
        "cell": f"{arch}:{shape_name}", "family": mod.FAMILY,
        "hlo_flops_dev": m["flops"], "hlo_bytes_dev": m["bytes"],
        "coll_bytes_dev": m["coll"], **terms,
        "dominant": dominant.replace("_s", ""),
        "model_flops_dev": mf, "useful_flops_ratio": ratio,
        "roofline_fraction": roofline_frac,
        "scan_corrected": corrected,
        "note": rec.get("note", ""),
    }


def fmt_table(rows):
    hdr = ("| cell | compute s | memory s | collective s | dominant | "
           "MODEL/HLO | roofline frac |\n|---|---|---|---|---|---|---|")
    out = [hdr]
    for r in rows:
        ratio = f"{r['useful_flops_ratio']:.2f}" \
            if r["useful_flops_ratio"] else "—"
        frac = f"{r['roofline_fraction']:.3f}" \
            if r["roofline_fraction"] else "—"
        out.append(
            f"| {r['cell']} | {r['compute_s']:.4f} | {r['memory_s']:.4f} | "
            f"{r['collective_s']:.4f} | **{r['dominant']}** | {ratio} | "
            f"{frac} |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--dryrun-dir", default="experiments/dryrun/pod256")
    ap.add_argument("--cache-dir", default="experiments/roofline/scancorr")
    ap.add_argument("--out", default="experiments/roofline/roofline.json")
    args = ap.parse_args()

    from repro.launch.mesh import make_production_mesh
    from repro.launch.cells import all_cells
    mesh = make_production_mesh(multi_pod=False)

    rows = []
    for arch, shape_name in all_cells():
        if args.arch and arch not in (args.arch,
                                      args.arch.replace("-", "_")):
            continue
        if args.shape and shape_name != args.shape:
            continue
        rec = _load(args.dryrun_dir, arch, shape_name)
        if rec is None:
            print(f"[roofline] missing dry-run for {arch}:{shape_name}")
            continue
        row = analyze_cell(arch, shape_name, rec, mesh, args.cache_dir)
        rows.append(row)
        print(f"[roofline] {row['cell']}: comp={row['compute_s']:.4f}s "
              f"mem={row['memory_s']:.4f}s coll={row['collective_s']:.4f}s "
              f"-> {row['dominant']}"
              + (f" frac={row['roofline_fraction']:.3f}"
                 if row['roofline_fraction'] else ""), flush=True)

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(rows, f, indent=1)
    with open(args.out.replace(".json", ".md"), "w") as f:
        f.write(fmt_table(rows) + "\n")
    print(f"[roofline] wrote {len(rows)} rows -> {args.out}")


if __name__ == "__main__":
    main()
