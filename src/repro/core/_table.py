"""Shared machinery for the gathered-boundary-table phase (paper Alg. 2).

Both distributed backends — the N-D block decomposition of structured grids
(`distributed.py`) and the vertex partition of unstructured edge-list meshes
(`distributed_graph.py`) — end their local phase with ONE all_gather of owned
boundary/cut labels into a replicated flat table, then resolve cross-shard
segments by post-processing that table identically on every device.  The
post-processing is backend-agnostic once two lookups are fixed:

  * how a *label value* maps to its table slot (coordinate arithmetic for
    blocks, a sorted-gid search for graphs) — a `lookup` closure;
  * which table slots are adjacent across shard cuts — a `cut_max` closure.

This module holds the backend-independent pieces: the pointer-doubling chase
(Alg. 2 lines 15-25), the equal-label group machinery and hook+propagate
fixpoint of deviation (d2) in DESIGN.md, and the value-search substitution
(Alg. 2 lines 27-33 generalised to merged labels).

Sentinel contract (deviation (p) in DESIGN.md): ragged decompositions pad
their gathered tables with slots whose label is -1 and whose mask is False.
Everything here is sentinel-aware by construction — `pointer_chase` fixes
entries < 0 (the backend `lookup` closures gate on `t >= 0`), the cut hooks
fed to `hook_propagate` gate on the gathered mask (False at padding, so a
pad slot can never hook or be hooked), and `value_substitute` leaves
negative labels untouched — so pad slots can never leak a label into a real
component, nor acquire one.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax


def pointer_chase(T, lookup, max_iter: int = 64):
    """Pointer doubling on the gathered flat table (Alg. 2 lines 15-25).

    `lookup(t)` maps every entry of the current table `t` through the table
    itself (entry value -> slot -> entry at that slot), leaving unresolvable
    entries (unmasked `< 0`, non-boundary targets) fixed.  Iterates to the
    fixpoint; returns (compressed table, rounds executed).
    """
    def cond(s):
        _, ch, i = s
        return ch & (i < max_iter)

    def body(s):
        t, _, i = s
        nt = lookup(t)
        return nt, jnp.any(nt != t), i + jnp.int32(1)

    T, _, iters = lax.while_loop(cond, body,
                                 (T, jnp.asarray(True), jnp.int32(0)))
    return T, iters


def make_group_max(Tstar):
    """Equal-label group structure of a compressed table.

    Slots sharing a label after the chase belong to the same (partial)
    component; groups are realised as runs of the sorted table so a group
    reduction is one `segment_max` (sorted-runs trick, no hash table).
    Returns (group_max fn, perm, sorted_vals); the latter two also drive the
    final value-search substitution.
    """
    msize = Tstar.size
    perm = jnp.argsort(Tstar)
    sorted_vals = Tstar[perm]
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_vals[1:] != sorted_vals[:-1]])
    run_id = jnp.cumsum(run_start) - 1
    inv_perm = jnp.zeros(msize, dtype=jnp.int32).at[perm].set(
        jnp.arange(msize, dtype=jnp.int32))

    def group_max(L):
        gm = jax.ops.segment_max(L[perm], run_id, num_segments=msize)
        return gm[run_id][inv_perm]

    return group_max, perm, sorted_vals


def hook_propagate(Tstar, cut_max, group_max, max_iter: int = 64):
    """Hook + propagate fixpoint on the compressed table (deviation (d2) in
    DESIGN.md): alternate `cut_max` (max across masked cut edges between
    table slots) and `group_max` (max within equal-original-label groups)
    until no label changes.  Computes, per slot, the largest label of its
    *global* component.  The paper compresses the ghost table with path
    compression only; that cannot *merge* components whose local roots are
    interior vertices — this fixpoint can, and stays within the paper's
    single-communication-phase budget (it only post-processes the
    already-gathered table).
    """
    def cond(st):
        _, ch, i = st
        return ch & (i < max_iter)

    def body(st):
        L, _, i = st
        nxt = group_max(cut_max(L))
        return nxt, jnp.any(nxt != L), i + jnp.int32(1)

    L, _, iters = lax.while_loop(
        cond, body, (Tstar, jnp.asarray(True), jnp.int32(0)))
    return L, iters


def value_substitute(o, chased, sorted_vals, g_sorted):
    """Final substitution for CC (Alg. 2 lines 27-33 generalised): take each
    owned label `chased` through the table, then adopt its equal-label
    group's propagated maximum, found by *value* (searchsorted over the
    sorted table) — by value because an owned label can name an interior
    root that is not itself a table slot but shares its value with cut
    vertices of the same local piece.  `o` is the pre-chase label; `< 0`
    (unmasked) entries stay -1.
    """
    idx = jnp.clip(jnp.searchsorted(sorted_vals, chased),
                   0, sorted_vals.shape[0] - 1)
    found = sorted_vals[idx] == chased
    improved = jnp.where(found & (chased >= 0),
                         jnp.maximum(g_sorted[idx], chased), chased)
    return jnp.where(o < 0, -1, improved)
