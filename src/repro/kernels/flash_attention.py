"""Pallas TPU kernel: fused flash attention (fwd), GQA-aware.

The LM substrate's compute hot spot (prefill_32k would otherwise materialise
S x S scores).  Canonical TPU schedule: grid (batch*heads, nQ, nK) with the
kv axis innermost (sequential on TPU), online-softmax state (m, l, acc) in
VMEM scratch carried across kv steps, finalised on the last kv block.
MXU-aligned block sizes (multiples of 128 on the contracted dims).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, block_q, block_k, seq_q, seq_k):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32) * scale        # (BQ, D)
    k = k_ref[0].astype(jnp.float32)                # (BK, D)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (BQ, BK)

    if causal:
        iq = pl.program_id(1)
        qpos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 0) + (seq_k - seq_q)
        kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(qpos >= kpos, s, _NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * corr + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, causal: bool = False, block_q: int = 128,
                    block_k: int = 128, interpret: bool = True):
    """q: (B, H, Sq, D); k, v: (B, Hkv, Skv, D) with H % Hkv == 0.

    Returns (B, H, Sq, D).  GQA is handled by an index-map trick: kv blocks
    for query head h come from kv head h // group — no jnp.repeat copy.
    """
    b, h, sq, d = q.shape
    _, hkv, sk, _ = k.shape
    group = h // hkv
    scale = 1.0 / np.sqrt(d)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    assert sq % block_q == 0 and sk % block_k == 0

    qr = q.reshape(b * h, sq, d)
    kr = k.reshape(b * hkv, sk, d)
    vr = v.reshape(b * hkv, sk, d)

    grid = (b * h, sq // block_q, sk // block_k)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_q=sq, seq_k=sk)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (bh // group, ik, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, iq, ik: (bh // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d),
                               lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out.reshape(b, h, sq, d)
