"""Deadline-aware flush scheduling for the async serving plane
(DESIGN.md §Serve-v2).

PR 6's engine flushed on every `submit_batch`, so bucket occupancies were
whatever one caller happened to hand over and the pow2 batch capacities
rarely filled.  The `FlushScheduler` decouples *admission* from *execution*:
work items enqueue into per-bucket FIFO queues and a bucket flushes only

  * when it reaches its batch capacity (the pow2 capacity actually fills),
  * when the earliest deadline in it would otherwise be missed — `now >=
    deadline - estimate`, where the estimate is a measured per-layout
    execute time (EWMA of observed durations, `default_estimate` before the
    first observation), or
  * on explicit `drain()` (`pop_all`).

Time is injected, never read from the wall directly: `MonotonicClock` for
production, `VirtualClock` for tests and benchmarks — a deterministic
virtual time source the test advances by hand, which makes deadline-flush
sequences exactly reproducible (the testability deviation recorded in
DESIGN.md §Serve-v2).  Durations observed through a `VirtualClock` are 0
unless the engine charges measured wall time back to the clock
(`charge_execution_time`), so virtual-clock runs degrade gracefully to
"flush exactly at the deadline".
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any


class VirtualClock:
    """Deterministic time source: advances only when told to."""

    def __init__(self, start: float = 0.0):
        self._t = float(start)

    def now(self) -> float:
        return self._t

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"cannot advance time backwards (dt={dt})")
        self._t += float(dt)
        return self._t


class MonotonicClock:
    """Wall time source (monotonic, so deadline arithmetic never jumps)."""

    def now(self) -> float:
        return time.monotonic()


@dataclasses.dataclass
class _Entry:
    """One queued work item with its admission metadata."""
    item: Any
    deadline: float | None
    enqueued_at: float


class FlushScheduler:
    """Per-bucket FIFO queues with capacity- and deadline-driven flushes.

    The scheduler only *decides* when a bucket should flush; popping and
    executing is the engine's job (`AsyncTopologyEngine._flush`), so the
    decision logic stays a pure function of (queues, clock, estimates) and
    unit-testable without compiling anything.
    """

    def __init__(self, capacity: int = 64, clock=None,
                 default_estimate: float = 0.0, ewma: float = 0.5):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.clock = clock if clock is not None else MonotonicClock()
        self.default_estimate = float(default_estimate)
        self.ewma = float(ewma)
        self._queues: dict = {}       # bucket key -> list[_Entry]
        self._estimates: dict = {}    # bucket key -> EWMA execute seconds

    # --- admission ------------------------------------------------------------

    def enqueue(self, key, item, deadline: float | None = None) -> int:
        """Queue one work item under its bucket key; returns the bucket's
        occupancy after the enqueue."""
        q = self._queues.setdefault(key, [])
        q.append(_Entry(item=item,
                        deadline=None if deadline is None else float(deadline),
                        enqueued_at=self.clock.now()))
        return len(q)

    def depth(self) -> int:
        """Total queued items across every bucket."""
        return sum(len(q) for q in self._queues.values())

    def depths(self) -> dict:
        return {k: len(q) for k, q in self._queues.items() if q}

    # --- flush decisions ------------------------------------------------------

    def full(self) -> list:
        """Bucket keys at (or beyond — a single request can expand past the
        capacity) their batch capacity."""
        return [k for k, q in self._queues.items() if len(q) >= self.capacity]

    def earliest_deadline(self, key) -> float | None:
        ds = [e.deadline for e in self._queues.get(key, ())
              if e.deadline is not None]
        return min(ds) if ds else None

    def flush_at(self, key) -> float | None:
        """Latest time the bucket can still flush without missing its
        earliest deadline: deadline minus the measured execute estimate."""
        d = self.earliest_deadline(key)
        return None if d is None else d - self.estimate(key)

    def due(self) -> list:
        """Bucket keys whose earliest deadline would be missed by waiting
        any longer."""
        now = self.clock.now()
        out = []
        for k, q in self._queues.items():
            if not q:
                continue
            t = self.flush_at(k)
            if t is not None and now >= t:
                out.append(k)
        return out

    def next_due_time(self) -> float | None:
        """Earliest `flush_at` across buckets (a poll-loop wakeup hint)."""
        times = [t for k in self._queues
                 if (t := self.flush_at(k)) is not None and self._queues[k]]
        return min(times) if times else None

    # --- draining -------------------------------------------------------------

    def pop(self, key) -> list:
        """Remove and return a bucket's queued entries (FIFO order)."""
        return self._queues.pop(key, [])

    def pop_all(self) -> dict:
        """Remove and return every non-empty queue (drain)."""
        out = {k: q for k, q in self._queues.items() if q}
        self._queues = {}
        return out

    # --- execute-time estimates ----------------------------------------------

    def observe(self, key, seconds: float) -> None:
        """Fold one measured bucket-execution duration into the per-layout
        estimate (EWMA; the first observation replaces the default)."""
        prev = self._estimates.get(key)
        self._estimates[key] = (float(seconds) if prev is None else
                                self.ewma * float(seconds)
                                + (1.0 - self.ewma) * prev)

    def estimate(self, key) -> float:
        return self._estimates.get(key, self.default_estimate)


__all__ = ["FlushScheduler", "VirtualClock", "MonotonicClock"]
