"""Serving plane v3: admission control, slack-ordered scheduling, load
shedding, and the shared compile cache (DESIGN.md §Serve-v3).

The overload contract pinned here: past the admission budgets `submit()`
returns already-failed handles with typed `Overloaded` errors (never an
exception out of the plane), queued requests whose deadline became
unmeetable are shed with typed `DeadlineShed` errors before wasting an
execution, deadline flushes run in slack order, and engines attached to
one `SharedExecutableCache` compile each executable exactly once between
them.  Every request that is NOT shed or rejected stays bit-identical to
the sequential `repro.topology.submit_many` path.  All timing runs on the
injected `VirtualClock`, so every policy decision in this file is exactly
reproducible.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.ids import compute_order
from repro.topology import TopologyRequest, submit_many
from repro.serve import (TopologyEngine, AsyncTopologyEngine, FlushScheduler,
                         VirtualClock, SharedExecutableCache, PlaneError,
                         Overloaded, DeadlineShed, COLD_START_ESTIMATE)
from repro.serve.workload import overload_trace


def _assert_results_equal(got, want):
    assert got.query == want.query and got.tag == want.tag
    for f in ("labels", "ascending", "descending", "segmentation"):
        a, b = getattr(got, f), getattr(want, f)
        assert (a is None) == (b is None), f
        if a is not None:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f)


def _flush_sum(stats):
    return (stats.flush_capacity + stats.flush_deadline + stats.flush_drain
            + stats.flush_retry)


def _cc(rng, shape=(9, 7), tag=None):
    return TopologyRequest("cc", mask=jnp.asarray(rng.random(shape) < 0.6),
                           connectivity=4, tag=tag)


def _ms(rng, shape=(9, 7), tag=None):
    field = jnp.asarray(rng.standard_normal(shape))
    return TopologyRequest("ms", order=compute_order(field), connectivity=4,
                           tag=tag)


# --- scheduler: cold-start estimate (satellite bugfix) ------------------------


def test_cold_start_flush_is_earlier_than_deadline():
    # regression (ISSUE 10): with the old default_estimate=0.0 a
    # never-measured bucket's flush_at equalled its earliest deadline, so
    # the FIRST request in every bucket flushed exactly AT its deadline
    # and missed it by the execution time
    clk = VirtualClock()
    sch = FlushScheduler(capacity=64, clock=clk)
    sch.enqueue("k", "first", deadline=1.0)
    assert sch.estimate("k") == COLD_START_ESTIMATE > 0.0
    assert sch.flush_at("k") == 1.0 - COLD_START_ESTIMATE < 1.0
    # an explicit 0.0 restores the legacy flush-at-deadline behavior
    legacy = FlushScheduler(capacity=64, clock=clk, default_estimate=0.0)
    legacy.enqueue("k", "first", deadline=1.0)
    assert legacy.flush_at("k") == 1.0


def test_global_ewma_seeds_cold_buckets():
    sch = FlushScheduler(capacity=64, clock=VirtualClock())
    sch.observe("a", 0.2)
    sch.observe("b", 0.4)
    # a cold bucket on a warm plane estimates like its peers (global EWMA
    # over all observations: 0.5*0.4 + 0.5*0.2), not the cold default
    assert sch.estimate("never-seen") == pytest.approx(0.3)
    assert sch.estimate("a") == pytest.approx(0.2)     # per-key wins
    assert sch.estimate("b") == pytest.approx(0.4)


# --- scheduler: slack ordering / shedding -------------------------------------


def test_due_is_slack_ordered():
    clk = VirtualClock()
    sch = FlushScheduler(capacity=64, clock=clk)
    sch.enqueue("x", 1, deadline=5.0)
    sch.enqueue("y", 1, deadline=3.0)
    sch.enqueue("z", 1, deadline=4.0)
    clk.advance(10.0)
    # all overdue; most negative slack (earliest flush_at) first, not dict
    # insertion order
    assert sch.due() == ["y", "z", "x"]
    assert sch.slack("y") < sch.slack("z") < sch.slack("x") < 0


def test_shed_policies():
    def fresh():
        clk = VirtualClock()
        sch = FlushScheduler(capacity=64, clock=clk)
        sch.enqueue("k", "missed", deadline=1.0)      # already late at t=2
        sch.enqueue("k", "doomed", deadline=3.5)      # unmeetable: 2+2>3.5
        sch.enqueue("k", "fine", deadline=10.0)
        sch.enqueue("k", "nodeadline")
        sch.observe("k", 2.0)
        clk.advance(2.0)
        return sch

    sch = fresh()
    assert sch.shed("never") == [] and sch.depth() == 4
    sch = fresh()
    assert [e.item for _, e in sch.shed("late")] == ["missed"]
    assert sch.depth() == 3
    sch = fresh()
    assert [e.item for _, e in sch.shed("hopeless")] == ["missed", "doomed"]
    assert sch.depth() == 2
    with pytest.raises(ValueError):
        sch.shed("aggressive")


def test_scheduler_purge():
    sch = FlushScheduler(capacity=64, clock=VirtualClock())
    sch.enqueue("a", ("r0", 0))
    sch.enqueue("a", ("r1", 0))
    sch.enqueue("b", ("r0", 1))
    out = sch.purge(lambda item: item[0] == "r0")
    assert sorted(e.item for e in out) == [("r0", 0), ("r0", 1)]
    assert sch.depth() == 1 and "b" not in sch.depths()


# --- scheduler: property-based random ops (satellite test coverage) -----------


def test_scheduler_property_random_ops():
    """Seeded random enqueue/advance/observe sequences: due() never
    returns an empty or non-overdue bucket, slack ordering is monotone,
    and shed() drops exactly the policy-unmeetable entries."""
    for seed in range(6):
        rng = np.random.default_rng(7000 + seed)
        clk = VirtualClock()
        sch = FlushScheduler(capacity=4, clock=clk)
        keys = ["a", "b", "c", "d"]
        for step in range(300):
            op = rng.random()
            if op < 0.5:
                dl = (None if rng.random() < 0.3
                      else float(clk.now() + rng.uniform(0.01, 2.0)))
                sch.enqueue(keys[int(rng.integers(4))], ("item", step), dl)
            elif op < 0.8:
                clk.advance(float(rng.uniform(0.0, 1.0)))
            else:
                sch.observe(keys[int(rng.integers(4))],
                            float(rng.uniform(0.0, 0.5)))
            due = sch.due()
            slacks = []
            for k in due:
                assert sch.depths().get(k), "due() returned an empty bucket"
                t = sch.flush_at(k)
                assert t is not None and clk.now() >= t, \
                    "due() returned a non-overdue bucket"
                slacks.append(sch.slack(k))
            assert slacks == sorted(slacks), "slack ordering not monotone"
            if rng.random() < 0.3:
                for k in due:
                    sch.pop(k)
        now = clk.now()
        dropped = sch.shed("hopeless")
        for k, e in dropped:
            assert e.deadline is not None
            assert now + sch.estimate(k) > e.deadline
        for k, n in sch.depths().items():   # survivors are all meetable
            for e in sch._queues[k]:
                assert (e.deadline is None
                        or now + sch.estimate(k) <= e.deadline)


# --- engine: admission control ------------------------------------------------


def test_admission_rejects_with_typed_overloaded():
    rng = np.random.default_rng(0)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=64,
                              clock=VirtualClock(), max_queue_depth=2)
    h0 = eng.submit(_cc(rng, tag=0), deadline=100.0)
    h1 = eng.submit(_cc(rng, tag=1), deadline=100.0)
    h2 = eng.submit(_cc(rng, tag=2), deadline=100.0)   # 2+1 > 2: rejected
    assert h2.done() and isinstance(h2.exception(), Overloaded)
    with pytest.raises(Overloaded):
        h2.result()
    s = eng.stats
    assert s.rejected == 1 and s.queue_depth_limit == 1
    assert s.requests == 2, "rejected submissions are not admitted requests"
    eng.drain()
    assert h0.exception() is None and h1.exception() is None
    want = submit_many([h0.request, h1.request])
    _assert_results_equal(h0.result(), want[0])
    _assert_results_equal(h1.result(), want[1])
    assert s.completed + s.failures + s.shed == s.requests
    # the queue drained: the next submission is admitted again
    h3 = eng.submit(_cc(rng, tag=3))
    eng.drain()
    assert h3.exception() is None and s.rejected == 1


def test_admission_rejects_on_inflight_cells():
    rng = np.random.default_rng(1)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=64,
                              clock=VirtualClock(),
                              max_inflight_cells=100)   # (9,7) = 63 cells
    h0 = eng.submit(_cc(rng, tag=0))
    h1 = eng.submit(_cc(rng, tag=1))       # 63+63 > 100: rejected
    assert h1.done() and isinstance(h1.exception(), Overloaded)
    assert "max_inflight_cells" in str(h1.exception())
    s = eng.stats
    assert s.rejected == 1 and s.queue_depth_limit == 0
    eng.drain()
    assert eng._inflight_cells == 0, "flushes must release the cell budget"
    h2 = eng.submit(_cc(rng, tag=2))       # budget released: admitted
    eng.drain()
    assert h2.exception() is None


# --- engine: load shedding ----------------------------------------------------


def test_shed_late_fails_handle_with_deadline_shed():
    rng = np.random.default_rng(2)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=64,
                              clock=VirtualClock(), shed_policy="late")
    h = eng.submit(_ms(rng, tag="ms"), deadline=1.0)    # expands to 2 items
    h2 = eng.submit(_cc(rng, tag="cc"), deadline=50.0)
    assert not h.done() and not h2.done()
    eng.advance(2.0)        # past h's deadline: shed BOTH its items, once
    assert h.done() and isinstance(h.exception(), DeadlineShed)
    with pytest.raises(DeadlineShed):
        h.result()
    s = eng.stats
    assert s.shed == 1 and s.batches == 0, \
        "shedding must not cost an execution"
    assert not h2.done()
    eng.drain()
    assert h2.exception() is None
    _assert_results_equal(h2.result(), submit_many([h2.request])[0])
    assert s.completed + s.failures + s.shed == s.requests == 2
    assert eng._inflight_cells == 0


def test_shed_hopeless_uses_estimate_never_keeps():
    rng = np.random.default_rng(3)
    # estimate 2.0 makes a 1.0-deadline hopeless at submit time
    eng = AsyncTopologyEngine(min_extent=8, max_batch=64,
                              clock=VirtualClock(), shed_policy="hopeless",
                              default_estimate=2.0)
    h = eng.submit(_cc(rng, tag=0), deadline=1.0)
    assert h.done() and isinstance(h.exception(), DeadlineShed)
    assert "shed_policy='hopeless'" in str(h.exception())
    # same setup under "never": the overdue flush_at fires immediately
    # instead, and the request COMPLETES (late, but bit-identical)
    keep = AsyncTopologyEngine(min_extent=8, max_batch=64,
                               clock=VirtualClock(), shed_policy="never",
                               default_estimate=2.0)
    hk = keep.submit(_cc(rng, tag=1), deadline=1.0)
    assert hk.done() and hk.exception() is None
    _assert_results_equal(hk.result(), submit_many([hk.request])[0])
    assert keep.stats.shed == 0 and keep.stats.deadline_misses == 0


# --- engine: deadline flushes follow slack ------------------------------------


def test_deadline_flush_order_follows_slack():
    rng = np.random.default_rng(4)
    eng = AsyncTopologyEngine(min_extent=8, max_batch=64,
                              clock=VirtualClock())
    eng.submit(_cc(rng, shape=(9, 7), tag="loose"), deadline=5.0)
    eng.submit(_cc(rng, shape=(6, 5), tag="tight"), deadline=3.0)
    eng.clock.advance(10.0)
    order = eng.scheduler.due()
    assert len(order) == 2
    assert eng.scheduler.earliest_deadline(order[0]) == 3.0, \
        "the tighter-slack bucket must flush first"
    assert eng.scheduler.earliest_deadline(order[1]) == 5.0
    assert eng.poll() == 2


# --- shared compile cache -----------------------------------------------------


def test_shared_cache_compiles_each_executable_once():
    rng = np.random.default_rng(5)
    reqs = [_cc(rng, tag=i) for i in range(3)]
    want = submit_many(reqs)
    cache = SharedExecutableCache(capacity=None)

    e1 = AsyncTopologyEngine(min_extent=8, max_batch=4, clock=VirtualClock(),
                             compile_cache=cache, name="r0")
    hs1 = [e1.submit(r) for r in reqs]
    e1.drain()
    compiles = cache.compiles
    assert compiles >= 1 and e1.stats.cache_misses == compiles

    # a second async replica on the SAME cache: zero new compiles
    e2 = AsyncTopologyEngine(min_extent=8, max_batch=4, clock=VirtualClock(),
                             compile_cache=cache, name="r1")
    hs2 = [e2.submit(r) for r in reqs]
    e2.drain()
    assert cache.compiles == compiles, "replica recompiled a shared layout"
    assert e2.stats.cache_misses == 0 and e2.stats.cache_hits >= 1

    # ... and the SYNC engine shares the same executables
    e3 = TopologyEngine(min_extent=8, max_batch=4, compile_cache=cache,
                        name="sync")
    got3 = e3.submit_batch(reqs)
    assert cache.compiles == compiles
    assert e3.stats.cache_misses == 0

    # attribution stays per engine even though the store is shared
    att = cache.attribution()
    assert att["r0"]["misses"] == compiles
    assert att["r1"]["misses"] == 0 and att["r1"]["hits"] >= 1
    assert att["sync"]["misses"] == 0 and att["sync"]["hits"] >= 1
    assert len(cache) == compiles     # no evictions at capacity=None
    assert len(e1._exec) == len(e2._exec) == len(cache)

    for h1, h2, g3, w in zip(hs1, hs2, got3, want):
        _assert_results_equal(h1.result(), w)
        _assert_results_equal(h2.result(), w)
        _assert_results_equal(g3, w)


def test_private_caches_stay_independent():
    rng = np.random.default_rng(6)
    req = _cc(rng, tag=0)
    a = TopologyEngine(min_extent=8, max_batch=4)
    b = TopologyEngine(min_extent=8, max_batch=4)
    a.submit_batch([req])
    b.submit_batch([req])
    # without a shared cache each engine pays its own compile (the pre-v3
    # behavior, unchanged by default)
    assert a.stats.cache_misses == 1 and b.stats.cache_misses == 1
    assert a.cache is not b.cache


# --- acceptance: 4x-oversubscribed open-loop trace ----------------------------


def test_overload_acceptance_4x_oversubscribed():
    """ISSUE 10 acceptance: under a 4x-oversubscribed open-loop trace on a
    VirtualClock, every admitted request completes bit-identically to
    sequential submit_many, the remainder is shed/rejected with typed
    errors (none escape the plane), flush order follows deadline slack,
    and two engines attached to one SharedExecutableCache compile each
    executable exactly once."""
    trace = overload_trace(24, ((9, 7), (6, 5)),
                           mix=(("cc", 0.7), ("ms", 0.3)), connectivity=4,
                           seed=7, sustainable_rps=40.0, factor=4.0)
    cache = SharedExecutableCache(capacity=None)

    def run(name, policy="hopeless"):
        eng = AsyncTopologyEngine(min_extent=8, max_batch=4,
                                  clock=VirtualClock(), max_queue_depth=6,
                                  shed_policy=policy,
                                  compile_cache=cache, name=name)
        due_orders = []
        orig_due = eng.scheduler.due

        def spying_due():
            keys = orig_due()
            due_orders.append([eng.scheduler.slack(k) for k in keys])
            return keys

        eng.scheduler.due = spying_due
        handles = []
        for req, (t, dl) in zip(trace.requests(), trace.arrivals):
            if t > eng.clock.now():
                eng.advance(t - eng.clock.now())
            handles.append(eng.submit(req, deadline=dl))
            assert _flush_sum(eng.stats) == eng.stats.batches
        eng.drain()
        assert _flush_sum(eng.stats) == eng.stats.batches
        return eng, handles, due_orders

    eng1, hs1, due1 = run("r0")
    compiles = cache.compiles
    assert compiles >= 1
    s = eng1.stats

    # typed errors only — nothing escapes the plane
    for h in hs1:
        assert h.done()
        assert h.exception() is None or isinstance(h.exception(), PlaneError)
    assert s.rejected > 0, "4x overload against depth=6 must reject"
    assert s.shed > 0, "hopeless policy under 4x overload must shed"
    assert s.completed > 0, "overload must not starve everything"
    assert s.failures == 0
    assert s.completed + s.shed + s.failures == s.requests
    assert s.rejected == sum(isinstance(h.exception(), Overloaded)
                             for h in hs1)
    assert s.shed == sum(isinstance(h.exception(), DeadlineShed)
                         for h in hs1)

    # bit-parity for every admitted-and-completed request
    completed = [h for h in hs1 if h.exception() is None]
    want = submit_many([h.request for h in completed])
    for h, w in zip(completed, want):
        _assert_results_equal(h.result(), w)

    # under "hopeless" a bucket whose flush_at has passed is by definition
    # already unmeetable, so its entries shed before due() ever returns it
    # — deadline flushes never fire, only capacity/drain flushes do
    assert all(o == [] for o in due1)
    assert eng1.stats.flush_deadline == 0

    # the same trace through a second engine on the same cache: identical
    # policy decisions (all-virtual determinism) and zero new compiles
    eng2, hs2, _ = run("r1")
    assert cache.compiles == compiles, \
        "second engine recompiled a shared executable"
    assert eng2.stats.cache_misses == 0
    assert (eng2.stats.rejected, eng2.stats.shed, eng2.stats.completed) == \
        (s.rejected, s.shed, s.completed)
    for a, b in zip(hs1, hs2):
        assert type(a.exception()) is type(b.exception())

    # under "never" deadline flushes DO fire, in slack order, and every
    # admitted request completes (late but bit-identical)
    eng3, hs3, due3 = run("r2", policy="never")
    assert any(len(o) > 0 for o in due3), "never-policy run saw no deadline" \
        " pressure — trace not oversubscribed enough"
    for order in due3:
        assert order == sorted(order), "deadline flushes out of slack order"
    s3 = eng3.stats
    assert s3.shed == 0 and s3.failures == 0 and s3.rejected > 0
    assert s3.completed == s3.requests
    done3 = [h for h in hs3 if h.exception() is None]
    want3 = submit_many([h.request for h in done3])
    for h, w in zip(done3, want3):
        _assert_results_equal(h.result(), w)
