"""Pure-numpy oracles for the DPC core (brute-force reference semantics)."""
from __future__ import annotations

import numpy as np

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.steepest import neighbor_offsets  # noqa: E402


def grid_neighbors(shape, connectivity):
    """Yield (flat_v, flat_u) directed neighbor pairs of a structured grid."""
    offs = neighbor_offsets(len(shape), connectivity)
    idx = np.arange(int(np.prod(shape))).reshape(shape)
    pairs = []
    for off in offs:
        src_sl, dst_sl = [], []
        for o, s in zip(off, shape):
            if o >= 0:
                src_sl.append(slice(0, s - o))
                dst_sl.append(slice(o, s))
            else:
                src_sl.append(slice(-o, s))
                dst_sl.append(slice(0, s + o))
        pairs.append((idx[tuple(src_sl)].ravel(), idx[tuple(dst_sl)].ravel()))
    send = np.concatenate([p[0] for p in pairs])
    recv = np.concatenate([p[1] for p in pairs])
    return send, recv


def oracle_manifold(order, connectivity=6, descending=True):
    """Follow the steepest path vertex-by-vertex (paper §3.3 definition)."""
    shape = order.shape
    flat = order.ravel().astype(np.int64)
    n = flat.size
    send, recv = grid_neighbors(shape, connectivity)
    # adjacency list
    neigh = [[] for _ in range(n)]
    for s, r in zip(send, recv):
        neigh[s].append(r)
    key = flat if descending else -flat
    target = np.empty(n, dtype=np.int64)
    for v in range(n):
        best, bestk = v, key[v]
        for u in neigh[v]:
            if key[u] > bestk:
                best, bestk = u, key[u]
        target[v] = best
    # follow to fixpoint
    out = np.arange(n)
    for v in range(n):
        cur = v
        while target[cur] != cur:
            cur = target[cur]
        out[v] = cur
    return out.reshape(shape)


def oracle_components(mask, connectivity=6):
    """BFS connected components of the masked grid; label = max vertex id."""
    shape = mask.shape
    flat = mask.ravel().astype(bool)
    n = flat.size
    send, recv = grid_neighbors(shape, connectivity)
    neigh = [[] for _ in range(n)]
    for s, r in zip(send, recv):
        if flat[s] and flat[r]:
            neigh[s].append(r)
    labels = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    for v in range(n):
        if not flat[v] or seen[v]:
            continue
        stack, comp = [v], [v]
        seen[v] = True
        while stack:
            x = stack.pop()
            for u in neigh[x]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(u)
                    comp.append(u)
        m = max(comp)
        for u in comp:
            labels[u] = m
    return labels.reshape(shape)


def oracle_components_graph(mask, senders, receivers):
    n = len(mask)
    neigh = [[] for _ in range(n)]
    for s, r in zip(senders, receivers):
        if mask[s] and mask[r]:
            neigh[s].append(r)
            neigh[r].append(s)
    labels = np.full(n, -1, dtype=np.int64)
    seen = np.zeros(n, dtype=bool)
    for v in range(n):
        if not mask[v] or seen[v]:
            continue
        stack, comp = [v], [v]
        seen[v] = True
        while stack:
            x = stack.pop()
            for u in neigh[x]:
                if not seen[u]:
                    seen[u] = True
                    stack.append(u)
                    comp.append(u)
        m = max(comp)
        for u in comp:
            labels[u] = m
    return labels
