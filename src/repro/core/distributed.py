"""Distributed Path Compression (paper Alg. 1 + Alg. 2) under shard_map.

Decomposition: 1-D slabs along grid axis 0 over a mesh axis (default
"shards"), one ghost plane per face — the paper's "one layer of ghost
vertices".  All pointers are *global* flat ids throughout; global<->local
index conversion is pure integer arithmetic for slab decomposition (replacing
TTK's triangulation id-translation structures).

Phases (MS manifolds):
  1. halo exchange of the order field (lax.ppermute, one plane per face);
  2. steepest init on the extended block; ghost-plane vertices pretend to be
     maxima (point to themselves) — Alg. 1 lines 6-8;
  3. local path compression to the block fixpoint (no collectives);
  4. ONE global communication step: all_gather of the two owned boundary
     planes' compressed pointers — the SPMD equivalent of Alg. 2's
     Gather->rank0->Scatter->Allgather staging (deviation (b) in DESIGN.md);
  5. pointer doubling on the gathered (P, 2, R) ghost table — every device
     compresses the same table, resolving segments that stretch across
     multiple ranks (paper Fig. 2);
  6. final substitution: owned pointers that target any boundary vertex are
     replaced by the table's compressed target — Alg. 2 lines 27-33.

Connected components add the stitch pass locally (Alg. 3) and, on the
gathered table, a hook+propagate fixpoint over cut edges and equal-label
groups.  The paper compresses the ghost table with path compression only;
that is sufficient for MS integral lines (strictly order-increasing chains)
but not for CC labels that must *merge* across a cut whose local roots are
interior vertices — deviation (d2) in DESIGN.md.  The fix stays within the
paper's single-communication-phase budget: it only post-processes the
already-gathered table.
"""
from __future__ import annotations

import math
from functools import partial
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .steepest import grid_steepest, grid_mask_argmax, neighbor_offsets
from .pathcompress import path_compress

AXIS = "shards"


class DPCStats(NamedTuple):
    local_iters: jax.Array      # pointer-doubling rounds in the local phase
    table_iters: jax.Array      # rounds on the gathered ghost table
    stitch_rounds: jax.Array    # CC only (0 for MS)
    ghost_bytes: jax.Array      # bytes all-gathered (the ONE comm phase)
    masked_ghost_fraction: jax.Array  # CC: fraction of boundary actually masked


def make_dpc_mesh(n_shards: int, devices=None) -> Mesh:
    return jax.make_mesh((n_shards,), (AXIS,), devices=devices)


# --- shared helpers ---------------------------------------------------------


def _halo(plane_from_prev, plane_from_next, p, n_shards, fill, axis):
    """ghost_lo[p] = plane_from_prev = block[p-1][-1]; symmetric for hi."""
    if n_shards == 1:
        lo = jnp.full_like(plane_from_prev, fill)
        hi = jnp.full_like(plane_from_next, fill)
        return lo, hi
    lo = lax.ppermute(plane_from_prev, axis,
                      [(i, i + 1) for i in range(n_shards - 1)])
    hi = lax.ppermute(plane_from_next, axis,
                      [(i + 1, i) for i in range(n_shards - 1)])
    lo = jnp.where(p == 0, fill, lo)
    hi = jnp.where(p == n_shards - 1, fill, hi)
    return lo, hi


def _local_compress(d_ext, base, max_iter=64):
    """Path compression with global-id pointers confined to the extended
    block: local position = gid - base.  Negative entries (unmasked CC
    sentinels / edge-shard ghost self-ids) are fixed points."""
    size = d_ext.size

    def jump(d):
        flat = d.ravel()
        lidx = jnp.clip(flat - base, 0, size - 1)
        nd = flat[lidx]
        return jnp.where(flat >= 0, nd, flat).reshape(d.shape)

    def cond(s):
        _, ch, i = s
        return ch & (i < max_iter)

    def body(s):
        d, _, i = s
        nd = jump(d)
        return nd, jnp.any(nd != d), i + jnp.int32(1)

    d, _, iters = lax.while_loop(cond, body,
                                 (d_ext, jnp.asarray(True), jnp.int32(0)))
    return d, iters


def _boundary_pos(gid, x_local, n_shards, R):
    """Map a global id to its (row, col) in the gathered (P, 2, R) table.
    Returns (is_boundary, flat_row_index)."""
    x = gid // R
    r = gid % R
    s = x // x_local
    xin = x % x_local
    is_b = ((xin == 0) | (xin == x_local - 1)) & (s >= 0) & (s < n_shards)
    j = jnp.where(xin == x_local - 1, 1, 0)
    return is_b, (s * 2 + j) * R + r


def _table_compress(T, x_local, n_shards, R, max_iter=64):
    """Pointer doubling on the gathered ghost table (Alg. 2 lines 15-25).
    Entries < 0 (unmasked, CC only) are fixed."""
    def lookup(t):
        g = t.ravel()
        is_b, pos = _boundary_pos(jnp.clip(g, 0), x_local, n_shards, R)
        tv = t.ravel()[jnp.clip(pos, 0, t.size - 1)]
        return jnp.where((g >= 0) & is_b, tv, g).reshape(t.shape)

    def cond(s):
        _, ch, i = s
        return ch & (i < max_iter)

    def body(s):
        t, _, i = s
        nt = lookup(t)
        return nt, jnp.any(nt != t), i + jnp.int32(1)

    T, _, iters = lax.while_loop(cond, body,
                                 (T, jnp.asarray(True), jnp.int32(0)))
    return T, iters


# --- MS manifolds ------------------------------------------------------------


def _manifold_block(order_blk, *, n_shards, connectivity, axis):
    """Always runs the *descending* direction; the ascending manifold is
    obtained by flipping the order field outside (keeps the -1 halo fill
    strictly below every candidate)."""
    p = lax.axis_index(axis)
    x_local = order_blk.shape[0]
    rest = order_blk.shape[1:]
    R = int(np.prod(rest))

    # 1. order halo (fill -1: below every real order value, never steepest)
    lo, hi = _halo(order_blk[-1], order_blk[0], p, n_shards, -1, axis)
    ext = jnp.concatenate([lo[None], order_blk, hi[None]], axis=0)

    # 2. steepest init with global ids; ghosts pretend to be maxima
    base = (p * x_local - 1) * R
    ptr = grid_steepest(ext, connectivity, descending=True,
                        id_offset=base).reshape(ext.shape)
    gids = jnp.arange(ext.size, dtype=jnp.int32).reshape(ext.shape) + base
    xs = jnp.arange(x_local + 2)
    is_ghost = ((xs == 0) | (xs == x_local + 1)).reshape(
        (-1,) + (1,) * len(rest))
    d_ext = jnp.where(is_ghost, gids, ptr)

    # 3. local compression (Alg. 1 lines 9-19)
    d_ext, local_iters = _local_compress(d_ext, base)

    # 4. the single communication phase (Alg. 2)
    bt = jnp.stack([d_ext[1].ravel(), d_ext[x_local].ravel()])  # (2, R)
    T = lax.all_gather(bt, axis)                                # (P, 2, R)

    # 5. ghost-table compression (identical on every device)
    T, table_iters = _table_compress(T, x_local, n_shards, R)

    # 6. final substitution (Alg. 2 lines 27-33)
    owned = d_ext[1:x_local + 1].ravel()
    is_b, pos = _boundary_pos(owned, x_local, n_shards, R)
    final = jnp.where(is_b, T.ravel()[jnp.clip(pos, 0, T.size - 1)], owned)

    stats = DPCStats(
        local_iters=lax.pmax(local_iters, axis),
        table_iters=table_iters,  # identical on all devices (same table)
        stitch_rounds=jnp.int32(0),
        ghost_bytes=jnp.float32(T.size) * 4,
        masked_ghost_fraction=jnp.float32(1.0),
    )
    return final.reshape(order_blk.shape), stats


def distributed_manifold(order, mesh: Mesh, connectivity: int = 6,
                         descending: bool = True):
    """Descending (or ascending) manifold of a slab-sharded order field.

    order: (X, ...) int array, X divisible by mesh axis size.  Returns the
    label grid (sharded the same way) and replicated DPCStats.
    """
    n_shards = mesh.shape[AXIS]
    if order.shape[0] % n_shards:
        raise ValueError(f"axis 0 ({order.shape[0]}) not divisible by "
                         f"{n_shards} shards")
    if not descending:
        order = order.size - 1 - order  # ascending = descending on flipped order
    fn = partial(_manifold_block, n_shards=n_shards,
                 connectivity=connectivity, axis=AXIS)
    ndim = order.ndim
    sharded = P(AXIS, *([None] * (ndim - 1)))
    mapped = jax.shard_map(
        fn, mesh=mesh, in_specs=(sharded,),
        out_specs=(sharded, DPCStats(*([P()] * 5))), check_vma=False)
    return mapped(order)


# --- connected components ----------------------------------------------------


def _ext_stitch(d, mask_ext, connectivity, base, sentinel_pos):
    """Stitch on the extended block with global-id labels (Alg. 3 ll. 25-29):
    scatter-max at local position d[v]-base."""
    from .steepest import shift_fill  # local import to avoid cycle at module load
    out = d.ravel()
    m = mask_ext
    for off in neighbor_offsets(d.ndim, connectivity):
        u_label = shift_fill(d, off, -1).ravel()
        valid = m.ravel() & shift_fill(m, off, False).ravel() & (u_label >= 0)
        tgt = jnp.where(valid, out - base, sentinel_pos)
        out = out.at[tgt].max(jnp.where(valid, u_label, -1), mode="drop")
    return out.reshape(d.shape)


def _cc_local_fixpoint(d_ext, mask_ext, connectivity, base, max_rounds=64):
    d, it0 = _local_compress(d_ext, base)
    size = d_ext.size

    def cond(s):
        _, ch, r, _ = s
        return ch & (r < max_rounds)

    def body(s):
        cur, _, r, its = s
        st = _ext_stitch(cur, mask_ext, connectivity, base, size)
        nxt, it = _local_compress(st, base)
        return nxt, jnp.any(nxt != cur), r + jnp.int32(1), its + it

    d, _, rounds, its = lax.while_loop(
        cond, body, (d, jnp.asarray(True), jnp.int32(0), it0))
    return d, rounds, its


def _cut_shifts(ndim, connectivity):
    """Trailing-dim offsets of neighbor pairs that cross a slab cut (dx=+1)."""
    return [off[1:] for off in neighbor_offsets(ndim, connectivity)
            if off[0] == 1]


def _table_propagate(Tstar, Mtab, cut_shifts, rest_shape, max_iter=64):
    """Hook + propagate on the gathered table: fixpoint of
      (a) max across masked cut edges (plane (i,1) <-> plane (i+1,0)),
      (b) max within equal-original-label groups (sorted-runs segment_max).
    Computes, for every boundary position, the largest label of its global
    component.  Deviation (d2): the paper's path compression alone cannot
    perform these merges."""
    from .steepest import shift_fill
    n_shards = Tstar.shape[0]
    flat_vals = Tstar.ravel()
    msize = flat_vals.shape[0]
    perm = jnp.argsort(flat_vals)
    sorted_vals = flat_vals[perm]
    run_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_vals[1:] != sorted_vals[:-1]])
    run_id = jnp.cumsum(run_start) - 1
    inv_perm = jnp.zeros(msize, dtype=jnp.int32).at[perm].set(
        jnp.arange(msize, dtype=jnp.int32))

    def group_max(L):
        ls = L.ravel()[perm]
        gm = jax.ops.segment_max(ls, run_id, num_segments=msize)
        return gm[run_id][inv_perm].reshape(L.shape)

    def cut_max(L):
        # L, Mtab: (P, 2, *rest); position (i,1,q) <-> (i+1,0,q+s)
        for s in cut_shifts:
            a = L[:-1, 1]            # plane i (last owned)
            b = L[1:, 0]             # plane i+1 (first owned)
            ma = Mtab[:-1, 1]
            mb = Mtab[1:, 0]
            b_at_a = shift_fill(b, (0,) + tuple(s), -1)
            mb_at_a = shift_fill(mb, (0,) + tuple(s), False)
            new_a = jnp.where(ma & mb_at_a, jnp.maximum(a, b_at_a), a)
            neg = tuple(-x for x in s)
            a_at_b = shift_fill(a, (0,) + neg, -1)
            ma_at_b = shift_fill(ma, (0,) + neg, False)
            new_b = jnp.where(mb & ma_at_b, jnp.maximum(b, a_at_b), b)
            L = L.at[:-1, 1].set(new_a).at[1:, 0].set(new_b)
        return L

    def cond(st):
        _, ch, i = st
        return ch & (i < max_iter)

    def body(st):
        L, _, i = st
        nxt = group_max(cut_max(L))
        return nxt, jnp.any(nxt != L), i + jnp.int32(1)

    L, _, iters = lax.while_loop(
        cond, body, (Tstar, jnp.asarray(True), jnp.int32(0)))
    return L, (perm, sorted_vals, run_id), iters


def _cc_block(mask_blk, *, n_shards, connectivity, axis,
              gather_mask: bool = True):
    """gather_mask=False is the §Perf variant: the boundary mask is exactly
    (T >= 0) — labels are -1 where unmasked — so the mask all-gather is
    redundant and dropped (20% less exchange traffic, bit-identical)."""
    p = lax.axis_index(axis)
    x_local = mask_blk.shape[0]
    rest = mask_blk.shape[1:]
    R = int(np.prod(rest))

    # 1. mask halo
    lo, hi = _halo(mask_blk[-1], mask_blk[0], p, n_shards, False, axis)
    mask_ext = jnp.concatenate([lo[None], mask_blk, hi[None]], axis=0)

    # 2. init: largest masked neighbor id; masked ghosts pretend self
    base = (p * x_local - 1) * R
    d0 = grid_mask_argmax(mask_ext, connectivity,
                          id_offset=base).reshape(mask_ext.shape)
    gids = jnp.arange(mask_ext.size, dtype=jnp.int32).reshape(
        mask_ext.shape) + base
    xs = jnp.arange(x_local + 2)
    is_ghost = ((xs == 0) | (xs == x_local + 1)).reshape(
        (-1,) + (1,) * len(rest))
    d_ext = jnp.where(is_ghost & mask_ext, gids, d0)

    # 3. local CC fixpoint (stitch + compress, Alg. 3)
    d_ext, stitch_rounds, local_iters = _cc_local_fixpoint(
        d_ext, mask_ext, connectivity, base)

    # 4. the single communication phase: labels (+ masks) of boundary planes
    bt = jnp.stack([d_ext[1].reshape(rest), d_ext[x_local].reshape(rest)])
    T = lax.all_gather(bt, axis)   # (P, 2, *rest)
    if gather_mask:
        bm = jnp.stack([mask_ext[1], mask_ext[x_local]])
        M = lax.all_gather(bm, axis)
    else:
        M = T >= 0                 # labels are -1 exactly where unmasked

    # 5a. positional chase (the paper's table compression — resolves chains
    #     through ghost labels, e.g. a part labeled with a ghost's id)
    Tstar, table_iters = _table_compress(
        T.reshape(n_shards, 2, R), x_local, n_shards, R)
    Tstar = Tstar.reshape((n_shards, 2) + rest)
    # 5b. hook + propagate (deviation (d2)): merge labels across cuts
    G, (perm, sorted_vals, run_id), prop_iters = _table_propagate(
        Tstar, M, _cut_shifts(mask_ext.ndim, connectivity), rest)

    # 6. substitution: chase own label through the table, then take its
    #    group's propagated maximum (value search over the sorted table)
    owned = d_ext[1:x_local + 1].ravel()
    is_b, pos = _boundary_pos(jnp.clip(owned, 0), x_local, n_shards, R)
    chased = jnp.where((owned >= 0) & is_b,
                       Tstar.ravel()[jnp.clip(pos, 0, Tstar.size - 1)], owned)
    idx = jnp.searchsorted(sorted_vals, chased)
    idx_c = jnp.clip(idx, 0, sorted_vals.shape[0] - 1)
    found = sorted_vals[idx_c] == chased
    g_sorted = G.ravel()[perm]
    improved = jnp.where(found & (chased >= 0),
                         jnp.maximum(g_sorted[idx_c], chased), chased)
    final = jnp.where(owned < 0, -1, improved)

    masked_frac = jnp.mean(M.astype(jnp.float32))
    stats = DPCStats(
        local_iters=lax.pmax(local_iters, axis),
        table_iters=table_iters + prop_iters,
        stitch_rounds=lax.pmax(stitch_rounds, axis),
        ghost_bytes=jnp.float32(T.size) * 4
        + (jnp.float32(M.size) if gather_mask else 0.0),
        masked_ghost_fraction=masked_frac,
    )
    return final.reshape(mask_blk.shape), stats


def distributed_connected_components(mask, mesh: Mesh, connectivity: int = 6,
                                     gather_mask: bool = True):
    """Mask-implicit connected components of a slab-sharded grid (Alg. 3 +
    Alg. 2).  Returns (labels, DPCStats); labels carry the largest vertex id
    of the component, -1 where unmasked.  gather_mask=False drops the
    redundant mask exchange (§Perf)."""
    n_shards = mesh.shape[AXIS]
    if mask.shape[0] % n_shards:
        raise ValueError(f"axis 0 ({mask.shape[0]}) not divisible by "
                         f"{n_shards} shards")
    fn = partial(_cc_block, n_shards=n_shards, connectivity=connectivity,
                 axis=AXIS, gather_mask=gather_mask)
    ndim = mask.ndim
    sharded = P(AXIS, *([None] * (ndim - 1)))
    mapped = jax.shard_map(
        fn, mesh=mesh, in_specs=(sharded,),
        out_specs=(sharded, DPCStats(*([P()] * 5))), check_vma=False)
    return mapped(mask)
