"""llama3.2-1b [dense]: 16L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=128256 — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified]"""
import jax.numpy as jnp

from repro.models.lm import LMConfig
from .lm_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name="llama3.2-1b", n_layers=16, d_model=2048, n_heads=32,
        n_kv_heads=8, d_ff=8192, vocab=128256, d_head=64,
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="llama3.2-1b-smoke", n_layers=2, d_model=64, n_heads=16,
        n_kv_heads=4, d_ff=128, vocab=128, d_head=4, loss_chunks=2)
