"""kimi-k2-1t-a32b [moe]: 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384e top-8 — trillion-param MoE (paper-table)
[arXiv:2501.kimi2; unverified]"""
import jax.numpy as jnp

from repro.models.lm import LMConfig, MoEConfig
from .lm_shapes import SHAPES, SMOKE_SHAPES  # noqa: F401

FAMILY = "lm"


def full_config() -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-a32b", n_layers=61, d_model=7168, n_heads=64,
        n_kv_heads=8, d_ff=2048, vocab=163840, d_head=112,
        moe=MoEConfig(n_experts=384, top_k=8, n_shared=1),
        param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="kimi-k2-1t-smoke", n_layers=2, d_model=64, n_heads=16,
        n_kv_heads=4, d_ff=32, vocab=128, d_head=4,
        moe=MoEConfig(n_experts=16, top_k=2, n_shared=1), loss_chunks=2)
