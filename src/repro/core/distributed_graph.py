"""Distributed connected components on unstructured (edge-list) meshes.

The paper computes CC "in distributed structured and unstructured grids,
based either on the connectivity of the underlying mesh or a feature mask"
(paper §5); `distributed.py` covers the structured block lattice — this
module covers the unstructured side with the same phase structure, swapping
coordinate arithmetic for *table-driven* id maps:

  decomposition  GraphDecomp vertex-partitions a global edge list into
                 per-device local subgraphs plus a one-ring ghost layer
                 (the unstructured analog of BlockDecomp's ghost faces);
                 every global<->local id translation is a precomputed
                 lookup table instead of stride arithmetic.
  local phase    graph steepest-init (graph_mask_argmax with masked ghosts
                 pinned to self, Alg. 1 lines 6-8) + path compression +
                 the stitch fixpoint (Alg. 3, deviation (d) in DESIGN.md)
                 run entirely device-local — no collectives.
  ONE comm phase lax.all_gather of every partition's owned *cut* vertices
                 (owned vertices incident to an inter-partition edge) into
                 a replicated flat table; labels and the cut-vertex masks
                 ride the same gather (deviation (b) in DESIGN.md).
  resolution     pointer chase over the table (Alg. 2 lines 15-25, slot
                 lookup by sorted-gid search), then the hook+propagate
                 fixpoint over the static cut-edge list and equal-label
                 groups (deviation (d2) in DESIGN.md), then value-search
                 substitution — all shared with the block backend via
                 core/_table.py, executed identically on every device.

Ghost *input* values (the mask at ghost vertices) are materialised by the
input scatter `mask[local_gid]` rather than exchanged with ppermute — the
unstructured analog of the structured halo; see deviation (g1) in DESIGN.md.
Fixed SPMD shapes are obtained by padding: the ghost/edge/cut tables pad to
their maxima (deviation (g2) in DESIGN.md), and each partition's owned set
pads to `max(counts)` with inert sentinel slots (deviation (p)), so
*imbalanced* (METIS-style) partitions — and vertex counts that do not
divide the partition count — are first-class.

`GraphDPCStats.comm_phases` counts the bulk exchange phases actually traced
into the program (the paper's budget: exactly one for the replicated
table).  `table_mode="sharded"` (deviation (s) in DESIGN.md) replaces the
cut-table all_gather with a partition-adjacency halo: each device keeps its
own cut row plus one chunk per adjacent partition (`_GraphShardGeom`),
exchanged by a static schedule of `lax.ppermute` rounds, and resolves the
global components by the relayed max-flooding fixpoint of
`core/_table.sharded_fixpoint` — bit-identical labels, per-device table
bytes bounded by (1 + degree) cut rows instead of `nparts` rows.
"""
from __future__ import annotations

from functools import partial

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ._shardmap import shard_map_norep
from ._table import (check_converged, check_table_mode, pointer_chase,
                     make_group_max, hook_propagate, sharded_fixpoint,
                     value_substitute)
from .stats import GraphDPCStats
from .steepest import graph_mask_argmax
from .connected_components import _cc_fixpoint, _graph_stitch

_N_STATS = len(GraphDPCStats._fields)


class GraphDecomp:
    """Static geometry of a vertex partition of an edge-list mesh.

    The mirror of BlockDecomp for unstructured meshes: where BlockDecomp
    derives ghost faces and boundary-table slots from coordinate strides,
    GraphDecomp precomputes them as numpy lookup tables from the concrete
    edge list (senders/receivers carry BOTH directions of every undirected
    edge, the repo-wide graph convention).

    Partition: `part[v]` assigns vertex v to one of `nparts` devices;
    default is contiguous blocks of global ids (the leading blocks one
    larger when ``n % nparts != 0``).  ANY explicit assignment works —
    imbalanced counts, empty partitions, a future METIS partitioner: each
    partition's owned set is padded to ``n_owned = max(counts)`` with inert
    sentinel slots (deviation (p) in DESIGN.md), the same fixed-SPMD-shape
    mechanism the ghost/edge/cut tables already use (deviation (g2)).

    Per partition p:
      owned    the sorted global ids with part == p (padded to `n_owned`;
               pad entries carry gid `n`, dropped by the output scatter);
      ghosts   the one-ring: vertices of other partitions reached by a cut
               edge from p;
      local id index into sorted(owned ∪ ghosts), padded at the end to
               `n_local`.  Sorting by *global* id preserves the invariant
               the id-maximum arguments rely on (as the block backend's
               raveled blocks do implicitly): the local id order is exactly
               the global id order restricted to the local set, so local
               argmax/stitch maxima transfer verbatim to global ids;
      edges    every directed global edge with >= 1 endpoint owned by p,
               rewritten to local ids (padded with (0, 0) self-loops, which
               are no-ops for argmax and stitch);
      cut      owned vertices incident to an inter-partition edge; cut j of
               p owns slot ``p * c_max + j`` of the gathered table.

    Ids use int32 below 2**31 vertices and int64 above (requires
    `jax_enable_x64`, mirroring BlockDecomp's refusal to wrap silently).
    """

    def __init__(self, n_vertices, senders, receivers, nparts, part=None):
        self.n = int(n_vertices)
        self.nparts = int(nparts)
        if self.n < 1:
            raise ValueError("graph must have at least one vertex")
        if self.nparts < 1:
            raise ValueError("nparts must be >= 1")
        if self.n < 2**31:
            self.id_dtype = jnp.int32
        elif jax.config.jax_enable_x64:
            self.id_dtype = jnp.int64
        else:
            # without x64, jnp silently downcasts int64 -> int32 and global
            # ids past 2**31 would wrap negative; refuse instead
            raise ValueError(
                f"graph has {self.n} >= 2**31 vertices; the int64 id path "
                "requires jax_enable_x64")
        s = np.asarray(senders, dtype=np.int64).ravel()
        r = np.asarray(receivers, dtype=np.int64).ravel()
        if s.shape != r.shape:
            raise ValueError("senders and receivers must have equal length")
        if s.size and not (0 <= s.min() and s.max() < self.n
                           and 0 <= r.min() and r.max() < self.n):
            raise ValueError("edge endpoints out of range")
        if part is None:
            # contiguous blocks; when n is not divisible the leading
            # n % nparts blocks are one vertex larger (no rounding of the
            # requested size — raggedness is padded away below)
            sizes = [len(c) for c in
                     np.array_split(np.arange(self.n), self.nparts)]
            part = np.repeat(np.arange(self.nparts), sizes)
        part = np.asarray(part, dtype=np.int64).ravel()
        if part.shape[0] != self.n:
            raise ValueError("part must assign every vertex")
        if part.size and (part.min() < 0 or part.max() >= self.nparts):
            raise ValueError(f"part values must lie in [0, {self.nparts})")
        counts = np.bincount(part, minlength=self.nparts)
        # no balance requirement: every partition's owned set pads to the
        # maximum count with inert sentinel slots (deviation (p) in
        # DESIGN.md), so arbitrary METIS-style assignments are accepted
        self.part = part
        self.owned_counts = counts
        self.n_owned = int(counts.max())
        self.pad_fraction = 1.0 - self.n / (self.nparts * self.n_owned)

        ps, pr = part[s], part[r]
        cross = ps != pr
        owned, ghosts, cut = [], [], []
        for p in range(self.nparts):
            owned.append(np.flatnonzero(part == p))
            sel = (ps == p) & cross
            ghosts.append(np.unique(r[sel]))
            cut.append(np.unique(s[sel]))
        self.g_max = max((len(g) for g in ghosts), default=0)
        self.n_local = self.n_owned + self.g_max
        if self.n_local >= 2**31:
            raise ValueError("per-partition extent exceeds int32 local ids; "
                             "use more partitions")
        self.c_max = max((len(c) for c in cut), default=0)
        self.table_size = self.nparts * self.c_max
        self.n_cut = int(sum(len(c) for c in cut))  # real (non-pad) slots

        # owned set padded to n_owned; pad gids are the out-of-range `n`,
        # which the output scatter drops (deviation (p) in DESIGN.md)
        self.owned_gid = np.full((self.nparts, self.n_owned), self.n,
                                 np.int64)
        lgid = np.full((self.nparts, self.n_local), -1, np.int64)
        valid = np.zeros((self.nparts, self.n_local), bool)
        is_ghost = np.zeros((self.nparts, self.n_local), bool)
        owned_lidx = np.zeros((self.nparts, self.n_owned), np.int32)
        cut_lidx = np.full((self.nparts, self.c_max), -1, np.int32)
        slot_of = np.full(self.n, -1, np.int64)
        gid2lid = np.full(self.n, -1, np.int64)              # reused scratch
        eloc = []
        for p in range(self.nparts):
            o, g, c = owned[p], ghosts[p], cut[p]
            self.owned_gid[p, :len(o)] = o
            loc = np.sort(np.concatenate([o, g]))  # local order == gid order
            lgid[p, :len(loc)] = loc
            valid[p, :len(loc)] = True
            gid2lid[loc] = np.arange(len(loc))
            is_ghost[p, gid2lid[g]] = True
            owned_lidx[p, :len(o)] = gid2lid[o]
            if len(o) < self.n_owned:
                # pad owned slots point at the first invalid local slot
                # (len(o) < n_owned implies len(loc) < n_local): mask False
                # there, so the pad label is -1 everywhere downstream
                owned_lidx[p, len(o):] = min(len(loc), self.n_local - 1)
            cut_lidx[p, :len(c)] = gid2lid[c]
            slot_of[c] = p * self.c_max + np.arange(len(c))
            esel = (ps == p) | (pr == p)
            ls, lr = gid2lid[s[esel]], gid2lid[r[esel]]
            if ls.size and ((ls < 0).any() or (lr < 0).any()):
                # reachable when a cross-partition edge appears in only one
                # direction: the receiving side then lacks the ghost
                raise ValueError(
                    "edge list must contain BOTH directions of every "
                    "undirected edge (one-ring ghost closure violated)")
            eloc.append((ls, lr))
            gid2lid[loc] = -1
        self.e_max = max((len(ls) for ls, _ in eloc), default=0)
        self.edge_src = np.zeros((self.nparts, self.e_max), np.int32)
        self.edge_dst = np.zeros((self.nparts, self.e_max), np.int32)
        for p, (ls, lr) in enumerate(eloc):
            self.edge_src[p, :len(ls)] = ls
            self.edge_dst[p, :len(lr)] = lr
        self.local_gid, self.local_valid = lgid, valid
        self.local_ghost = is_ghost
        self.owned_lidx = owned_lidx
        self.cut_lidx = cut_lidx

        # cut edges in table-slot space (both directions already present)
        self.cut_edge_src = slot_of[s[cross]].astype(np.int32)
        self.cut_edge_dst = slot_of[r[cross]].astype(np.int32)
        # sorted gid -> slot lookup for the pointer chase (the table-driven
        # stand-in for BlockDecomp.boundary_pos)
        allcut = np.concatenate(cut)
        order = np.argsort(allcut)
        self.cut_gid_sorted = allcut[order]
        self.cut_slot_sorted = slot_of[allcut[order]].astype(np.int32)


class _GraphShardGeom:
    """Sharded-table geometry of a vertex partition (deviation (s)).

    The unstructured analog of the block backend's `_ShardGeom`: where the
    lattice derives neighbor chunks from the mesh axes, here the *partition
    adjacency graph* (two partitions are adjacent iff a cut edge joins
    them) is read off the concrete cut-edge list.  Every partition's stack
    holds its own cut row (chunk 0) plus one chunk per adjacent partition,
    padded to the global maximum degree `d_max` with inert fill chunks.

    The halo exchange is a static schedule of `lax.ppermute` rounds: the
    directed receive pairs {(q -> p) : q adjacent to p} are greedily
    decomposed into partial permutations (ppermute forbids duplicate
    sources, so a partition multicasting its row to `deg` neighbors spans
    >= deg rounds; bipartite edge coloring bounds the schedule at d_max
    rounds, the greedy pass may use slightly more).  `store_idx[p, k]` says
    which chunk partition p stores round k's received row into — `n_chunks`
    (out of range, dropped) when p receives nothing that round.  All of
    this is numpy precomputed once per decomposition and threaded into the
    shard_map as per-device rows, like the other GraphDecomp tables.
    """

    def __init__(self, dec: GraphDecomp):
        c = dec.c_max
        pe_s = dec.cut_edge_src // max(c, 1)
        pe_d = dec.cut_edge_dst // max(c, 1)
        adjset = [set() for _ in range(dec.nparts)]
        for a, b in zip(pe_s.tolist(), pe_d.tolist()):
            adjset[a].add(b)
            adjset[b].add(a)
        adj = [sorted(s) for s in adjset]
        self.d_max = max((len(a) for a in adj), default=0)
        self.n_chunks = 1 + self.d_max
        self.stack_size = self.n_chunks * c
        chunk_of = np.full((dec.nparts, dec.nparts), -1, np.int32)
        for p in range(dec.nparts):
            chunk_of[p, p] = 0
            for i, q in enumerate(adj[p]):
                chunk_of[p, q] = 1 + i
        self.chunk_of = chunk_of

        pairs = [(q, p) for p in range(dec.nparts) for q in adj[p]]
        perms = []
        while pairs:
            used_s, used_d, rnd, rest = set(), set(), [], []
            for q, p in pairs:
                if q not in used_s and p not in used_d:
                    used_s.add(q)
                    used_d.add(p)
                    rnd.append((q, p))
                else:
                    rest.append((q, p))
            perms.append(tuple(rnd))
            pairs = rest
        self.round_perms = tuple(perms)
        store_idx = np.full((dec.nparts, max(len(perms), 1)), self.n_chunks,
                            np.int32)
        for k, rnd in enumerate(perms):
            for q, p in rnd:
                store_idx[p, k] = chunk_of[p, q]
        self.store_idx = store_idx

        # cut edges rewritten to per-partition stack slots: edge (u -> v)
        # appears in p's list iff BOTH endpoint partitions have a chunk in
        # p's stack; pad rows with src == stack_size (gated + dropped)
        srow = dec.cut_edge_src % max(c, 1)
        drow = dec.cut_edge_dst % max(c, 1)
        lists = []
        for p in range(dec.nparts):
            cs, cd = chunk_of[p, pe_s], chunk_of[p, pe_d]
            sel = (cs >= 0) & (cd >= 0)
            lists.append((cs[sel] * c + srow[sel], cd[sel] * c + drow[sel]))
        self.se_max = max((len(a) for a, _ in lists), default=0)
        ses = np.full((dec.nparts, max(self.se_max, 1)), self.stack_size,
                      np.int32)
        sed = np.zeros((dec.nparts, max(self.se_max, 1)), np.int32)
        for p, (a, b) in enumerate(lists):
            ses[p, :len(a)] = a
            sed[p, :len(b)] = b
        self.stack_edge_src = ses
        self.stack_edge_dst = sed


def _graph_shard_geom(dec: GraphDecomp) -> _GraphShardGeom:
    """The sharded geometry, built once per decomposition (numpy)."""
    geom = dec.__dict__.get("_shard_geom")
    if geom is None:
        geom = dec.__dict__["_shard_geom"] = _GraphShardGeom(dec)
    return geom


def _slot_lookup(dec: GraphDecomp):
    """(values -> (hit, slot)) via the sorted cut-gid table."""
    sg = jnp.asarray(dec.cut_gid_sorted, dtype=dec.id_dtype)
    sl = jnp.asarray(dec.cut_slot_sorted)

    def lookup(v):
        i = jnp.clip(jnp.searchsorted(sg, jnp.clip(v, 0)), 0, sg.size - 1)
        hit = (v >= 0) & (sg[i] == jnp.clip(v, 0))
        return hit, sl[i]

    return lookup


def _cc_partition(local_mask, lgid, local_ghost, owned_lidx, es, er,
                  cut_lidx, *shard, dec: GraphDecomp, name: str,
                  gather_mask: bool, table_mode: str = "replicated",
                  table_max_iter: int = 64):
    """One partition's program (runs under shard_map; leading axis is the
    singleton shard dim).  `shard` carries the sharded-geometry rows
    (store_idx, chunk_of, stack edges) when table_mode == "sharded"."""
    m = local_mask[0]
    gid = lgid[0]
    ghost = local_ghost[0]
    ol = owned_lidx[0]
    s, r = es[0], er[0]
    cl = cut_lidx[0]
    dt = dec.id_dtype

    # 1.+2. init: largest masked neighbor id; masked ghosts pretend self
    d0 = graph_mask_argmax(m, s, r, ghost=ghost)

    # 3. local CC fixpoint (stitch + compress, Alg. 3) in local ids
    res = _cc_fixpoint(d0, lambda d: _graph_stitch(d, m, s, r, dec.n_local))

    # 4. to global ids
    dg = jnp.where(res.labels >= 0, gid[jnp.clip(res.labels, 0)], dt(-1))
    owned = dg[ol]

    isz = jnp.dtype(dt).itemsize
    if dec.table_size == 0:
        # no inter-partition edges (or a single partition): fully local
        final = owned
        table_iters = jnp.int32(0)
        ghost_bytes = jnp.float32(0.0)
        masked_frac = jnp.float32(0.0)
        comm = jnp.int32(0)
        exch_rounds = jnp.int32(0)
        table_bytes = jnp.float32(0.0)
        converged = jnp.int32(1)
    elif table_mode == "replicated":
        # 5. the ONE communication phase: owned cut labels (+ masks in the
        #    same gather; gather_mask=False derives M = T >= 0 instead,
        #    DESIGN.md §Perf)
        cvalid = cl >= 0
        cli = jnp.clip(cl, 0)
        cut_lab = jnp.where(cvalid, dg[cli], dt(-1))
        if gather_mask:
            cut_m = jnp.where(cvalid, m[cli], False)
            payload = jnp.stack([cut_lab, cut_m.astype(dt)])
        else:
            payload = cut_lab[None]
        g = lax.all_gather(payload, name)        # (nparts, rows, c_max)
        T = g[:, 0, :].reshape(-1)
        M = (g[:, 1, :].reshape(-1) != 0) if gather_mask else (T >= 0)

        # 6a. positional chase (Alg. 2 lines 15-25, table-driven lookup)
        slot_lookup = _slot_lookup(dec)

        def chase_lookup(t):
            hit, slot = slot_lookup(t)
            return jnp.where(hit, t[jnp.clip(slot, 0, t.size - 1)], t)

        Tstar, chase_iters, chase_ok = pointer_chase(T, chase_lookup,
                                                     table_max_iter)

        # 6b. hook + propagate over the static cut-edge list (deviation (d2))
        group_max, perm, sorted_vals = make_group_max(Tstar)
        ces = jnp.asarray(dec.cut_edge_src)
        ced = jnp.asarray(dec.cut_edge_dst)

        def cut_max(L):
            ok = M[ces] & M[ced]
            tgt = jnp.where(ok, ces, L.size)
            return L.at[tgt].max(jnp.where(ok, L[ced], dt(-1)), mode="drop")

        G, prop_iters, prop_ok = hook_propagate(Tstar, cut_max, group_max,
                                                table_max_iter)

        # 7. substitution: chase own label once, adopt its group's maximum
        hit, slot = slot_lookup(owned)
        chased = jnp.where(hit, Tstar[jnp.clip(slot, 0, Tstar.size - 1)],
                           owned)
        final = value_substitute(owned, chased, sorted_vals, G[perm])
        table_iters = chase_iters + prop_iters
        rows = 2 if gather_mask else 1
        # pad cut slots (cut_lidx == -1) carry label -1 / mask False and are
        # excluded from the exchange accounting (deviation (p) in DESIGN.md)
        ghost_bytes = jnp.float32(dec.n_cut * rows * isz)
        masked_frac = (jnp.sum(M).astype(jnp.float32)
                       / jnp.float32(max(dec.n_cut, 1)))
        comm = jnp.int32(1)
        exch_rounds = jnp.int32(0)
        # gathered payload (labels + mask as id dtype), or labels + bool M
        table_bytes = jnp.float32(
            dec.table_size * ((2 * isz) if gather_mask else (isz + 1)))
        converged = (chase_ok & prop_ok).astype(jnp.int32)
    else:
        # 5'-7'. sharded (deviation (s)): own cut row + one chunk per
        #    adjacent partition, max-flooding relayed by ppermute rounds —
        #    no all_gather.  The flood relation (masked in-stack cut edges +
        #    equal-static-label groups within the stack) connects exactly
        #    each global component's slots; its unique monotone fixpoint is
        #    the component max, the value the replicated chase+propagate
        #    computes (DESIGN.md §Table-sharding).
        geom = _graph_shard_geom(dec)
        store, chunk_row, ses, sed = (a[0] for a in shard)
        size = geom.stack_size
        cvalid = cl >= 0
        cli = jnp.clip(cl, 0)
        cut_lab = jnp.where(cvalid, dg[cli], dt(-1))

        def make_exchange(fill):
            def exchange(own_row):
                stack = jnp.full((geom.n_chunks, dec.c_max), fill,
                                 own_row.dtype)
                stack = stack.at[0].set(own_row)
                for k, perm_k in enumerate(geom.round_perms):
                    recv = lax.ppermute(own_row, name, perm_k)
                    stack = stack.at[store[k]].set(recv, mode="drop")
                return stack.reshape(-1)
            return exchange

        # static stacks, exchanged once: the group structure and the mask
        exchange = make_exchange(-1)
        T0s = exchange(cut_lab)
        if gather_mask:
            cut_m = jnp.where(cvalid, m[cli], False)
            Ms = make_exchange(False)(cut_m)
        else:
            Ms = T0s >= 0            # labels are -1 iff unmasked
        group_max, perm, sorted_vals = make_group_max(T0s)

        def cut_max(L):
            ss = jnp.clip(ses, 0, size - 1)
            dd = jnp.clip(sed, 0, size - 1)
            ok = (ses < size) & Ms[ss] & Ms[dd]
            tgt = jnp.where(ok, ss, size)
            return L.at[tgt].max(jnp.where(ok, L[dd], dt(-1)), mode="drop")

        def refine(stack):
            return hook_propagate(stack, cut_max, group_max, table_max_iter)

        def reduce_any(x):
            return lax.pmax(x.astype(jnp.int32), name) > 0

        stackG, _, rounds, iters, ok = sharded_fixpoint(
            cut_lab, exchange, refine, reduce_any,
            max_rounds=table_max_iter)

        # substitution: an owned label is a local vertex id, so its slot
        # (when it is a cut vertex) lives in this stack — own chunk or an
        # adjacent partition's; interior roots are found by value over the
        # static stack labels, exactly as in the replicated value search
        slot_lookup = _slot_lookup(dec)
        hit, slot = slot_lookup(owned)
        chunk = chunk_row[jnp.clip(slot // dec.c_max, 0, dec.nparts - 1)]
        sidx = chunk * dec.c_max + slot % dec.c_max
        chased = jnp.where(hit & (chunk >= 0),
                           stackG[jnp.clip(sidx, 0, size - 1)], owned)
        final = value_substitute(owned, chased, sorted_vals, stackG[perm])

        table_iters = lax.pmax(iters, name)
        exch_rounds = rounds
        comm = rounds + jnp.int32(1)     # +1: the static label/mask stacks
        halo = size - dec.c_max
        ghost_bytes = (jnp.float32(halo * isz)
                       * (rounds.astype(jnp.float32) + 1.0)
                       + (jnp.float32(halo) if gather_mask else 0.0))
        # evolving stack + static label stack + own row + bool mask stack
        table_bytes = jnp.float32((2 * size + dec.c_max) * isz + size)
        # global fraction over real slots (== the replicated number: pad
        # slots are mask-False on both paths, deviation (p))
        masked_frac = (lax.psum(
            jnp.sum(Ms[:dec.c_max]).astype(jnp.float32), name)
            / jnp.float32(max(dec.n_cut, 1)))
        converged = lax.pmin(ok.astype(jnp.int32), name)

    stats = GraphDPCStats(
        local_iters=lax.pmax(res.n_compress_iter, name),
        table_iters=table_iters,
        stitch_rounds=lax.pmax(res.n_rounds, name),
        ghost_bytes=ghost_bytes,
        masked_ghost_fraction=masked_frac,
        comm_phases=comm,
        pad_fraction=jnp.float32(dec.pad_fraction),
        kernel_rounds=jnp.int32(0),        # no fused grid kernel on graphs
        global_iters_saved=jnp.int32(0),
        table_bytes_peak=table_bytes,
        exchange_rounds=exch_rounds,
        converged=converged,
    )
    return final[None], stats


def _shard_geom_args(decomp: GraphDecomp, table_mode: str):
    """The per-device sharded-geometry rows threaded into the shard_map
    (empty for the replicated layout)."""
    if table_mode != "sharded" or decomp.table_size == 0:
        return ()
    geom = _graph_shard_geom(decomp)
    return (jnp.asarray(geom.store_idx), jnp.asarray(geom.chunk_of),
            jnp.asarray(geom.stack_edge_src),
            jnp.asarray(geom.stack_edge_dst))


def distributed_connected_components_graph(mask, decomp: GraphDecomp,
                                           mesh: Mesh,
                                           gather_mask: bool = True,
                                           table_mode: str = "replicated",
                                           table_max_iter: int = 64):
    """Mask-implicit connected components of a vertex-partitioned edge-list
    mesh (Alg. 3 + Alg. 2 on a table-driven decomposition).

    mask: global (n,) bool array (the feature mask; all-ones labels pure
    geometry).  mesh: 1-D device mesh with `decomp.nparts` devices (e.g.
    ``make_dpc_mesh(nparts)``).  table_mode picks the cut-table layout —
    "replicated" (one all_gather) or "sharded" (own cut row + one chunk per
    adjacent partition, ppermute exchange rounds; deviation (s) in
    DESIGN.md).  Returns (labels, GraphDPCStats): labels is the global (n,)
    array carrying the largest vertex id of each component, -1 where
    unmasked — bit-identical to single-device `connected_components_graph`
    under every table_mode.
    """
    check_table_mode(table_mode)
    names = tuple(mesh.axis_names)
    if len(names) != 1:
        raise ValueError(f"graph CC needs a 1-D mesh, got axes {names}")
    name = names[0]
    if int(mesh.shape[name]) != decomp.nparts:
        raise ValueError(f"mesh has {mesh.shape[name]} devices but decomp "
                         f"has {decomp.nparts} partitions")
    dt = decomp.id_dtype
    mask = mask.ravel().astype(bool)
    if mask.shape[0] != decomp.n:
        raise ValueError(f"mask has {mask.shape[0]} entries for "
                         f"{decomp.n} vertices")

    lgid = jnp.asarray(decomp.local_gid, dtype=dt)
    valid = jnp.asarray(decomp.local_valid)
    # ghost input values ride the input scatter (deviation (g1) in
    # DESIGN.md): every partition reads its owned + one-ring mask here
    local_mask = jnp.where(valid, mask[jnp.clip(lgid, 0)], False)
    geom_args = _shard_geom_args(decomp, table_mode)

    fn = partial(_cc_partition, dec=decomp, name=name,
                 gather_mask=gather_mask, table_mode=table_mode,
                 table_max_iter=table_max_iter)
    spec = P(name, None)
    mapped = shard_map_norep(fn, mesh, (spec,) * (7 + len(geom_args)),
                             (spec, GraphDPCStats(*([P()] * _N_STATS))))
    owned_stack, stats = mapped(
        local_mask, lgid, jnp.asarray(decomp.local_ghost),
        jnp.asarray(decomp.owned_lidx),
        jnp.asarray(decomp.edge_src), jnp.asarray(decomp.edge_dst),
        jnp.asarray(decomp.cut_lidx), *geom_args)
    check_converged(stats.converged, "distributed_connected_components_graph",
                    table_max_iter)

    # unpermute the (nparts, n_owned) owned labels back to global id order;
    # pad slots carry gid n and fall off the scatter (deviation (p))
    labels = jnp.zeros(decomp.n, dtype=dt).at[
        jnp.asarray(decomp.owned_gid.reshape(-1))].set(
        owned_stack.reshape(-1), mode="drop")
    return labels, stats


def distributed_connected_components_graph_batch(masks, decomp: GraphDecomp,
                                                 mesh: Mesh,
                                                 gather_mask: bool = True,
                                                 table_mode: str =
                                                 "replicated",
                                                 table_max_iter: int = 64):
    """Batched `distributed_connected_components_graph`: masks is a (B, n)
    stack of feature masks over ONE decomposed mesh (the multi-tenant
    serving case: many masks / thresholds of the same geometry).  The
    per-partition program is vmapped inside one shard_map, so the single
    cut-table all_gather fires once for the whole batch (DESIGN.md §Serve).
    Returns ((B, n) labels, GraphDPCStats with a leading (B,) dim); per item
    bit-identical to the single-request call.
    """
    check_table_mode(table_mode)
    names = tuple(mesh.axis_names)
    if len(names) != 1:
        raise ValueError(f"graph CC needs a 1-D mesh, got axes {names}")
    name = names[0]
    if int(mesh.shape[name]) != decomp.nparts:
        raise ValueError(f"mesh has {mesh.shape[name]} devices but decomp "
                         f"has {decomp.nparts} partitions")
    dt = decomp.id_dtype
    masks = masks.reshape(masks.shape[0], -1).astype(bool)
    if masks.shape[1] != decomp.n:
        raise ValueError(f"masks have {masks.shape[1]} entries for "
                         f"{decomp.n} vertices")
    B = masks.shape[0]

    lgid = jnp.asarray(decomp.local_gid, dtype=dt)
    valid = jnp.asarray(decomp.local_valid)
    # (nparts, B, n_local): the ghost-input scatter (deviation (g1)) for
    # every request at once
    local_mask = jnp.where(valid[:, None, :],
                           masks[:, jnp.clip(lgid, 0)].transpose(1, 0, 2),
                           False)
    geom_args = _shard_geom_args(decomp, table_mode)

    part_fn = partial(_cc_partition, dec=decomp, name=name,
                      gather_mask=gather_mask, table_mode=table_mode,
                      table_max_iter=table_max_iter)

    def fn(local_mask, lgid, ghost, ol, es, er, cl, *geom):
        # local_mask: (1, B, n_local); the rest carry the singleton shard dim
        def one(m):
            return part_fn(m[None], lgid, ghost, ol, es, er, cl, *geom)
        owned, stats = jax.vmap(one)(local_mask[0])   # owned: (B, 1, n_owned)
        return owned.transpose(1, 0, 2), stats

    spec = P(name, None)
    bspec = P(name, None, None)
    mapped = shard_map_norep(
        fn, mesh, (bspec,) + (spec,) * (6 + len(geom_args)),
        (bspec, GraphDPCStats(*([P(None)] * _N_STATS))))
    owned_stack, stats = mapped(
        local_mask, lgid, jnp.asarray(decomp.local_ghost),
        jnp.asarray(decomp.owned_lidx),
        jnp.asarray(decomp.edge_src), jnp.asarray(decomp.edge_dst),
        jnp.asarray(decomp.cut_lidx), *geom_args)
    check_converged(stats.converged,
                    "distributed_connected_components_graph_batch",
                    table_max_iter)

    labels = jnp.zeros((B, decomp.n), dtype=dt).at[
        :, jnp.asarray(decomp.owned_gid.reshape(-1))].set(
        owned_stack.transpose(1, 0, 2).reshape(B, -1), mode="drop")
    return labels, stats
