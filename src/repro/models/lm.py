"""Decoder-only LM substrate: GQA + RoPE + SwiGLU, optional fine-grained MoE
(shared + routed experts, top-k), layer-stacked `lax.scan` with remat,
flash-pattern chunked attention, chunked cross-entropy, KV-cache serving.

Sharding (logical; bound by the launcher through runtime.meshctx):
  params     — 2D FSDP x TP ("fsdp" on the d_model-ish dim, "tp" on
               heads / d_ff / vocab / experts)
  activations— batch on "dp", residual stream sequence-sharded on "sp"
               (Megatron-style sequence parallelism), attention heads on "tp"
  KV cache   — batch on "dp", cache sequence on "sp" ("ep_all" for the
               single-sequence long_500k cell: context-parallel decode)
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.nn.core import (dense_init, embed_init, rms_norm, rope,
                           cross_entropy_chunked)
from repro.kernels.ref import flash_attention_ref, mha_ref
from repro.runtime.meshctx import constrain


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 1
    capacity_factor: float = 1.25
    # "global": one cross-shard dispatch sort (paper-faithful naive EP);
    # "local": shard-local sort in GSPMD (refuted — see EXPERIMENTS §Perf);
    # "shard_map": manually-partitioned dispatch — local sort, local gather,
    #              local expert FFN, one psum over "model" (§Perf winner)
    dispatch: str = "global"
    dp_shards: int = 1         # static data-shard count for local dispatch


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                      # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    rope_theta: float = 500_000.0
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    remat: bool = True
    attn_block_kv: int = 512
    loss_chunks: int = 8
    seq_shard: bool = True               # sequence-parallel residual stream
    # §Perf knobs (hillclimbing — see EXPERIMENTS.md):
    remat_attn: bool = False     # checkpoint the flash scan (recompute in bwd)
    remat_loss: bool = False     # checkpoint per-chunk CE logits
    opt_moment_dtype: Any = jnp.float32  # bf16 halves optimizer HBM
    scan_unroll: int = 1         # roofline tooling: inline the layer scan
    fsdp: bool = True            # False: TP-only params (no per-layer
                                 # weight all-gathers; fits <=13B dense)

    @property
    def head_dim(self):
        return self.d_head or self.d_model // self.n_heads

    def n_params(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        if self.moe:
            ffn = (self.moe.n_experts + self.moe.n_shared) * 3 * d * self.d_ff
            ffn += d * self.moe.n_experts
        else:
            ffn = 3 * d * self.d_ff
        return (self.n_layers * (attn + ffn + 2 * d)
                + 2 * self.vocab * d + d)

    def n_active_params(self) -> int:
        if not self.moe:
            return self.n_params()
        d, dh = self.d_model, self.head_dim
        attn = d * (self.n_heads * dh) * 2 + d * (self.n_kv_heads * dh) * 2
        ffn = (self.moe.top_k + self.moe.n_shared) * 3 * d * self.d_ff
        ffn += d * self.moe.n_experts
        return (self.n_layers * (attn + ffn + 2 * d)
                + 2 * self.vocab * d + d)


# --- params ------------------------------------------------------------------


def init_params(key, cfg: LMConfig):
    d, dh = cfg.d_model, cfg.head_dim
    L = cfg.n_layers
    keys = jax.random.split(key, 16)
    dt = cfg.param_dtype

    def stack(k, *shape):
        fan_in = shape[-2]
        return dense_init(k, int(np.prod(shape[:-1])), shape[-1],
                          dt, scale=1.0 / np.sqrt(fan_in)).reshape(shape)

    layers = {
        "ln1": jnp.ones((L, d), dt),
        "ln2": jnp.ones((L, d), dt),
        "wq": stack(keys[0], L, d, cfg.n_heads * dh),
        "wk": stack(keys[1], L, d, cfg.n_kv_heads * dh),
        "wv": stack(keys[2], L, d, cfg.n_kv_heads * dh),
        "wo": stack(keys[3], L, cfg.n_heads * dh, d),
    }
    if cfg.moe:
        e, f = cfg.moe.n_experts, cfg.d_ff
        layers.update({
            "router": stack(keys[4], L, d, e),
            "we_gate": stack(keys[5], L, e, d, f),
            "we_up": stack(keys[6], L, e, d, f),
            "we_down": stack(keys[7], L, e, f, d),
            "ws_gate": stack(keys[8], L, d, cfg.moe.n_shared * f),
            "ws_up": stack(keys[9], L, d, cfg.moe.n_shared * f),
            "ws_down": stack(keys[10], L, cfg.moe.n_shared * f, d),
        })
    else:
        layers.update({
            "w_gate": stack(keys[4], L, d, cfg.d_ff),
            "w_up": stack(keys[5], L, d, cfg.d_ff),
            "w_down": stack(keys[6], L, cfg.d_ff, d),
        })
    return {
        "embed": embed_init(keys[11], cfg.vocab, d, dt),
        "layers": layers,
        "ln_f": jnp.ones((d,), dt),
        "unembed": dense_init(keys[12], d, cfg.vocab, dt),
    }


def param_logical_specs(cfg: LMConfig):
    """Logical PartitionSpec tree matching init_params' structure.  Stacked
    layer params carry a leading None for the scan dim.  cfg.fsdp=False
    drops the data-axis parameter sharding (§Perf: no weight all-gathers)."""
    layers = {
        "ln1": (None, None), "ln2": (None, None),
        "wq": (None, "fsdp", "tp"),
        "wk": (None, "fsdp", "tp"),
        "wv": (None, "fsdp", "tp"),
        "wo": (None, "tp", "fsdp"),
    }
    if cfg.moe:
        layers.update({
            "router": (None, "fsdp", None),
            "we_gate": (None, "tp", "fsdp", None),
            "we_up": (None, "tp", "fsdp", None),
            "we_down": (None, "tp", None, "fsdp"),
            "ws_gate": (None, "fsdp", "tp"),
            "ws_up": (None, "fsdp", "tp"),
            "ws_down": (None, "tp", "fsdp"),
        })
    else:
        layers.update({
            "w_gate": (None, "fsdp", "tp"),
            "w_up": (None, "fsdp", "tp"),
            "w_down": (None, "tp", "fsdp"),
        })
    tree = {
        "embed": ("tp", "fsdp"),
        "layers": layers,
        "ln_f": (None,),
        "unembed": ("fsdp", "tp"),
    }
    if not cfg.fsdp:
        import jax
        tree = jax.tree.map(
            lambda spec: tuple(None if a == "fsdp" else a for a in spec),
            tree, is_leaf=lambda x: isinstance(x, tuple))
    return tree


# --- attention ---------------------------------------------------------------


def _attention(x, lp, cfg: LMConfig, positions, kv=None, cache_len=None):
    """x: (B, S, D).  Training/prefill when kv is None (causal flash);
    decode when kv=(k_cache, v_cache) with valid length `cache_len` —
    new k/v are already written into the cache by the caller."""
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cdt = cfg.compute_dtype
    q = (x @ lp["wq"].astype(cdt)).reshape(b, s, h, dh)
    kx = (x @ lp["wk"].astype(cdt)).reshape(b, s, hkv, dh)
    vx = (x @ lp["wv"].astype(cdt)).reshape(b, s, hkv, dh)
    q = rope(q, positions, cfg.rope_theta)
    kx = rope(kx, positions, cfg.rope_theta)
    q = constrain(q.swapaxes(1, 2), "dp", "tp", None, None)    # (B,H,S,dh)
    kx = kx.swapaxes(1, 2)
    vx = vx.swapaxes(1, 2)

    if kv is None:
        blk = min(cfg.attn_block_kv, s)
        attn = partial(flash_attention_ref, causal=True, block_kv=blk)
        if cfg.remat_attn:
            attn = jax.checkpoint(attn)
        o = attn(q, kx, vx)
        new_kv = (kx, vx)
    else:
        k_cache, v_cache = kv   # (B, Hkv, S_max, dh), pre-updated
        s_max = k_cache.shape[2]
        group = h // hkv
        kk = jnp.repeat(k_cache, group, axis=1).astype(jnp.float32)
        vv = jnp.repeat(v_cache, group, axis=1).astype(jnp.float32)
        scores = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kk)
        scores = scores / math.sqrt(dh)
        valid = jnp.arange(s_max)[None, None, None, :] < cache_len
        scores = jnp.where(valid, scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        o = jnp.einsum("bhqk,bhkd->bhqd", w, vv).astype(cdt)
        new_kv = kv
    o = o.swapaxes(1, 2).reshape(b, s, h * dh)
    return o @ lp["wo"].astype(cdt), new_kv


# --- MoE ---------------------------------------------------------------------


def _expert_ffn(xg, lp, cdt):
    """xg: (E, C, D) -> (E, C, D) through per-expert SwiGLU."""
    hg = jnp.einsum("ecd,edf->ecf", xg, lp["we_gate"].astype(cdt))
    hu = jnp.einsum("ecd,edf->ecf", xg, lp["we_up"].astype(cdt))
    return jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hu,
                      lp["we_down"].astype(cdt))


def _dispatch_tables(topi, topv, T, E, C):
    """Capacity-bounded dispatch: token slots sorted by expert, ranked by
    stable position; returns (table, wtab) of shape (E*C,) where table holds
    source-token ids (T = padding sentinel)."""
    k = topi.shape[-1]
    ef = topi.reshape(-1)
    tok = jnp.broadcast_to(jnp.arange(T)[:, None], (T, k)).reshape(-1)
    w = topv.reshape(-1)
    order = jnp.argsort(ef, stable=True)
    es, toks, ws = ef[order], tok[order], w[order]
    pos = jnp.arange(T * k) - jnp.searchsorted(es, es, side="left")
    slot = jnp.where(pos < C, es * C + pos, E * C)
    table = jnp.full((E * C,), T, jnp.int32).at[slot].set(
        toks.astype(jnp.int32), mode="drop")
    wtab = jnp.zeros((E * C,), jnp.float32).at[slot].set(ws, mode="drop")
    return table, wtab


def _moe_ffn(x2d, lp, cfg: LMConfig):
    """x2d: (T, D).  Returns (out, aux_loss).  Global dispatch: one sort over
    all T*k slots (GSPMD turns this into a cross-shard sort — the §Perf
    baseline); dispatch="local" resorts per data shard, see _moe_ffn_local."""
    mcfg = cfg.moe
    T, d = x2d.shape
    E, k = mcfg.n_experts, mcfg.top_k
    cdt = cfg.compute_dtype
    logits = (x2d.astype(jnp.float32) @ lp["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    C = max(int(math.ceil(T * k / E * mcfg.capacity_factor)), 1)
    table, wtab = _dispatch_tables(topi, topv, T, E, C)
    # keep the (E, C, ...) layout end-to-end so the expert dim stays
    # tp-sharded through gather -> FFN -> scatter (reshaping it away forces
    # GSPMD to replicate the slot buffers)
    table = constrain(table.reshape(E, C), "tp", None)
    wtab = wtab.reshape(E, C)
    xp = jnp.concatenate([x2d, jnp.zeros((1, d), x2d.dtype)], axis=0)
    xg = constrain(xp[table], "tp", None, None)           # (E, C, d)
    out_slots = _expert_ffn(xg, lp, cdt) * wtab[..., None].astype(cdt)
    y = jnp.zeros((T + 1, d), cdt).at[table].add(out_slots)[:T]

    # shared experts (always-on dense branch)
    hs = jax.nn.silu(x2d @ lp["ws_gate"].astype(cdt)) * \
        (x2d @ lp["ws_up"].astype(cdt))
    y = y + hs @ lp["ws_down"].astype(cdt)

    # switch-style load-balance aux
    counts = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
    f = counts / (T * k)
    p_mean = probs.mean(axis=0)
    aux = E * jnp.sum(f * p_mean)
    return y, aux


def _moe_ffn_local(x2d, lp, cfg: LMConfig):
    """Shard-local dispatch (§Perf optimisation): tokens are viewed as
    (dp_shards, T_local) so sort/rank/scatter stay shard-local; the only
    cross-shard traffic is the expert-output reduce that GSPMD already emits
    for the TP contraction."""
    mcfg = cfg.moe
    T, d = x2d.shape
    dp = mcfg.dp_shards
    E, k = mcfg.n_experts, mcfg.top_k
    cdt = cfg.compute_dtype
    Tl = T // dp
    # pin the shard-local view: leading dim on "dp", everything else local
    # (the (B,S,D)->(T,D) reshape otherwise inherits the sp-sharded S and
    # GSPMD falls back to full rematerialisation of the scatter)
    xl = constrain(x2d.reshape(dp, Tl, d), "dp", None, None)
    logits = xl.astype(jnp.float32) @ lp["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    topv, topi = lax.top_k(probs, k)
    topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)

    C = max(int(math.ceil(Tl * k / E * mcfg.capacity_factor)), 1)
    tables, wtabs = jax.vmap(
        partial(_dispatch_tables, T=Tl, E=E, C=C))(topi, topv)
    xp = jnp.concatenate([xl, jnp.zeros((dp, 1, d), x2d.dtype)], axis=1)
    xg = jnp.take_along_axis(
        xp, tables[:, :, None], axis=1).reshape(dp, E, C, d)
    xg = constrain(xg, "dp", "tp", None, None)
    out = jax.vmap(lambda g: _expert_ffn(g, lp, cdt))(xg)
    out = out.reshape(dp, E * C, d) * wtabs[..., None].astype(cdt)
    y = jnp.zeros((dp, Tl + 1, d), cdt).at[
        jnp.arange(dp)[:, None], tables].add(out)[:, :Tl]
    # NOTE (§Perf, refuted-hypothesis record): this shard-local dispatch
    # removes the cross-shard dispatch sort (all-to-all -82%) but GSPMD's
    # scatter partitioner replicates the batched combine, growing
    # all-reduce + temp.  Localising it fully needs shard_map around the
    # MoE interior — documented future work in EXPERIMENTS.md.
    y = y.reshape(T, d)

    hs = jax.nn.silu(x2d @ lp["ws_gate"].astype(cdt)) * \
        (x2d @ lp["ws_up"].astype(cdt))
    y = y + hs @ lp["ws_down"].astype(cdt)

    counts = jax.vmap(lambda ti: jnp.zeros((E,), jnp.float32)
                      .at[ti.reshape(-1)].add(1.0))(topi).sum(0)
    f = counts / (T * k)
    p_mean = probs.reshape(T, E).mean(axis=0)
    aux = E * jnp.sum(f * p_mean)
    return y, aux


def _moe_ffn_shardmap(x2d, lp, cfg: LMConfig):
    """Manually-partitioned routed-expert path: every (data i, model j)
    device sorts ITS tokens, gathers ITS experts' slots from its local
    token block (x replicated over "model" within a data row), runs the
    expert FFN locally and contributes via ONE psum over "model" — no
    cross-shard sort, no GSPMD scatter guessing.  Shared experts and the
    aux loss stay in GSPMD land (tiny).  Falls back to the global path
    when no mesh is bound (unit tests)."""
    from repro.runtime.meshctx import get_current_mesh
    from jax.sharding import PartitionSpec as P
    mesh = get_current_mesh()
    if mesh is None or "model" not in mesh.axis_names:
        return _moe_ffn(x2d, lp, cfg)
    mcfg = cfg.moe
    T, d = x2d.shape
    E, k = mcfg.n_experts, mcfg.top_k
    cdt = cfg.compute_dtype
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp_total = 1
    for a in dp_axes:
        dp_total *= mesh.shape[a]
    tp = mesh.shape["model"]
    e_loc = E // tp
    t_loc = T // dp_total
    C = max(int(math.ceil(t_loc * k / E * mcfg.capacity_factor)), 1)

    def inner(x_loc, router, wg, wu, wd):
        # x_loc (t_loc, d); router (d, E); w* (e_loc, d, f)
        j = lax.axis_index("model")
        logits = x_loc.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        topv, topi = lax.top_k(probs, k)
        topv = topv / jnp.maximum(topv.sum(-1, keepdims=True), 1e-9)
        table, wtab = _dispatch_tables(topi, topv, t_loc, E, C)
        tbl = lax.dynamic_slice_in_dim(table.reshape(E, C), j * e_loc,
                                       e_loc, axis=0)
        wt = lax.dynamic_slice_in_dim(wtab.reshape(E, C), j * e_loc,
                                      e_loc, axis=0)
        xp = jnp.concatenate([x_loc, jnp.zeros((1, d), x_loc.dtype)], axis=0)
        xg = xp[tbl]                                   # (e_loc, C, d) local
        hg = jnp.einsum("ecd,edf->ecf", xg, wg.astype(cdt))
        hu = jnp.einsum("ecd,edf->ecf", xg, wu.astype(cdt))
        out = jnp.einsum("ecf,efd->ecd", jax.nn.silu(hg) * hu,
                         wd.astype(cdt))
        out = out * wt[..., None].astype(cdt)
        y = jnp.zeros((t_loc + 1, d), cdt).at[tbl].add(out)[:t_loc]
        y = lax.psum(y, "model")                       # combine experts
        counts = jnp.zeros((E,), jnp.float32).at[topi.reshape(-1)].add(1.0)
        return y, lax.psum(counts / tp, "model"), \
            lax.psum(probs.sum(0) / tp, "model")

    dspec = P(dp_axes if len(dp_axes) > 1 else dp_axes[0])
    from repro.core._shardmap import shard_map_norep
    y, counts, psum = shard_map_norep(
        inner, mesh,
        in_specs=(P(dspec[0], None), P(None, None),
                  P("model", None, None), P("model", None, None),
                  P("model", None, None)),
        out_specs=(P(dspec[0], None), P(dspec[0]), P(dspec[0])),
    )(x2d, lp["router"], lp["we_gate"], lp["we_up"], lp["we_down"])

    # shared experts + aux loss in GSPMD land
    hs = jax.nn.silu(x2d @ lp["ws_gate"].astype(cdt)) * \
        (x2d @ lp["ws_up"].astype(cdt))
    y = y + hs @ lp["ws_down"].astype(cdt)
    # counts/psum are per-data-shard partials stacked on the dp axis
    f = counts.reshape(dp_total, E).sum(0) / (T * k)
    p_mean = psum.reshape(dp_total, E).sum(0) / T
    aux = E * jnp.sum(f * p_mean)
    return y, aux


# --- blocks ------------------------------------------------------------------


_MOE_DISPATCH = {"global": _moe_ffn, "local": _moe_ffn_local,
                 "shard_map": _moe_ffn_shardmap}


def _ffn(x, lp, cfg: LMConfig):
    b, s, d = x.shape
    if cfg.moe is None:
        cdt = cfg.compute_dtype
        h = jax.nn.silu(x @ lp["w_gate"].astype(cdt)) * \
            (x @ lp["w_up"].astype(cdt))
        return h @ lp["w_down"].astype(cdt), jnp.float32(0.0)
    fn = _MOE_DISPATCH[cfg.moe.dispatch]
    y, aux = fn(x.reshape(b * s, d), lp, cfg)
    return y.reshape(b, s, d), aux


def _layer(x, lp, cfg: LMConfig, positions):
    x = constrain(x, "dp", "sp", None)
    a, _ = _attention(rms_norm(x, lp["ln1"]), lp, cfg, positions)
    x = x + a
    x = constrain(x, "dp", "sp", None)
    f, aux = _ffn(rms_norm(x, lp["ln2"]), lp, cfg)
    return x + f, aux


def forward(params, tokens, cfg: LMConfig):
    """tokens: (B, S) -> hidden states (B, S, D) after final norm."""
    cdt = cfg.compute_dtype
    x = params["embed"].astype(cdt)[tokens]
    x = constrain(x, "dp", "sp", None)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1]), tokens.shape)

    def body(carry, lp):
        y, aux = _layer(carry, lp, cfg, positions)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxs = lax.scan(body_fn, x, params["layers"],
                       unroll=cfg.scan_unroll)
    return rms_norm(x, params["ln_f"]), auxs.mean()


def loss_fn(params, batch, cfg: LMConfig, aux_weight: float = 0.01):
    h, aux = forward(params, batch["tokens"], cfg)
    ce_fn = partial(cross_entropy_chunked, n_chunks=cfg.loss_chunks)
    if cfg.remat_loss:
        ce_fn = jax.checkpoint(ce_fn, static_argnums=())
    ce = ce_fn(h, params["unembed"].astype(cfg.compute_dtype),
               batch["labels"])
    return ce + aux_weight * aux, {"ce": ce, "aux": aux}


# --- serving -----------------------------------------------------------------


def init_kv_cache(cfg: LMConfig, batch: int, max_len: int,
                  dtype=None):
    dt = dtype or cfg.compute_dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_len, cfg.head_dim)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "length": jnp.zeros((), jnp.int32)}


def prefill(params, tokens, cfg: LMConfig, max_len: int | None = None):
    """Run the causal forward over the prompt, return (last-token logits,
    populated KV cache)."""
    b, s = tokens.shape
    max_len = max_len or s
    cdt = cfg.compute_dtype
    x = params["embed"].astype(cdt)[tokens]
    positions = jnp.broadcast_to(jnp.arange(s), tokens.shape)

    def body(carry, lp):
        xx = constrain(carry, "dp", "sp", None)
        a, (kx, vx) = _attention(rms_norm(xx, lp["ln1"]), lp, cfg, positions)
        xx = xx + a
        f, aux = _ffn(rms_norm(xx, lp["ln2"]), lp, cfg)
        return xx + f, (kx, vx)

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, (ks, vs) = lax.scan(body_fn, x, params["layers"],
                           unroll=cfg.scan_unroll)
    h = rms_norm(x, params["ln_f"])
    logits = h[:, -1] @ params["unembed"].astype(cdt)
    pad = max_len - s
    if pad:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, 0), (0, pad), (0, 0)))
    cache = {"k": ks, "v": vs, "length": jnp.int32(s)}
    return logits, cache


def decode_step(params, cache, tokens, cfg: LMConfig):
    """One decoding step.  tokens: (B, 1) newest ids; cache length tracks the
    write position.  Returns (logits (B, V), new cache)."""
    cdt = cfg.compute_dtype
    b = tokens.shape[0]
    pos = cache["length"]
    x = params["embed"].astype(cdt)[tokens]          # (B, 1, D)
    positions = jnp.full((b, 1), pos, jnp.int32)

    def body(carry, layer_in):
        lp, kc, vc = layer_in
        xx = carry
        h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
        xn = rms_norm(xx, lp["ln1"])
        kx = (xn @ lp["wk"].astype(cdt)).reshape(b, 1, hkv, dh)
        vx = (xn @ lp["wv"].astype(cdt)).reshape(b, 1, hkv, dh)
        kx = rope(kx, positions, cfg.rope_theta).swapaxes(1, 2)
        vx = vx.swapaxes(1, 2)
        kc = lax.dynamic_update_slice_in_dim(kc, kx.astype(kc.dtype), pos,
                                             axis=2)
        vc = lax.dynamic_update_slice_in_dim(vc, vx.astype(vc.dtype), pos,
                                             axis=2)
        a, _ = _attention(xn, lp, cfg, positions, kv=(kc, vc),
                          cache_len=pos + 1)
        xx = xx + a
        f, _ = _ffn(rms_norm(xx, lp["ln2"]), lp, cfg)
        return xx + f, (kc, vc)

    x, (ks, vs) = lax.scan(body, x, (params["layers"], cache["k"],
                                     cache["v"]), unroll=cfg.scan_unroll)
    h = rms_norm(x, params["ln_f"])
    logits = h[:, 0] @ params["unembed"].astype(cdt)
    return logits, {"k": ks, "v": vs, "length": pos + 1}
